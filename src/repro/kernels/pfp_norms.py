"""Fused PFP normalization Pallas kernels (RMSNorm / LayerNorm).

Both norms are row-reductions followed by an affine map, so the kernel
blocks over rows and keeps the full (padded) feature axis resident in
VMEM: one pass computes the per-token normalizer from the second raw
moments, applies the deterministic scale to (mean, var), and — the
joint-operator principle again — optionally fuses the *following*
moment-matched activation as an epilogue so the normalized tile never
round-trips through HBM between the two ops.

Padding contract: feature columns are zero-padded to a lane multiple by
`ops.py`; the kernels divide reductions by the TRUE feature count `d`
(compile-time constant), and LayerNorm's spread is computed in moment
form  E[var + mean^2] - mu_tok^2  so zero-padded columns contribute
exact zeros to every accumulator.

Representation handling is static: `rep` selects whether the `second`
input holds variances or second raw moments, and the missing one is
derived in-register exactly like `GaussianTensor.var`/`.srm` would.

`block_rows` is the schedule axis the autotuner (repro.tuning) searches;
tuned values arrive through the `schedule` argument of
`ops.pfp_rmsnorm`/`ops.pfp_layernorm` (rows are padded to any block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gaussian import SRM, VAR
from repro.kernels.pfp_activations import MOMENT_FNS


def _split_reps(mu, second, rep):
    """(var, srm) from the stored second moment, fp32."""
    if rep == VAR:
        return second, second + jnp.square(mu)
    return second - jnp.square(mu), second


def _rmsnorm_kernel(mu_ref, sec_ref, gain_ref, mu_out_ref, sec_out_ref,
                    *, rep: str, d: int, eps: float, act):
    mu = mu_ref[...].astype(jnp.float32)
    sec = sec_ref[...].astype(jnp.float32)
    var, srm = _split_reps(mu, sec, rep)
    # E[rms^2] = mean_j E[x_j^2]: normalizer from the SRMs (delta method).
    norm = jax.lax.rsqrt(
        jnp.sum(srm, axis=-1, keepdims=True) / d + eps)
    scale = norm * gain_ref[...].astype(jnp.float32)
    mean = mu * scale
    var = var * jnp.square(scale)
    if act is not None:  # fused activation epilogue: VAR -> SRM
        mean, var = MOMENT_FNS[act](mean, var)
    mu_out_ref[...] = mean
    sec_out_ref[...] = var


def _layernorm_kernel(mu_ref, sec_ref, gain_ref, bias_ref,
                      mu_out_ref, sec_out_ref,
                      *, rep: str, d: int, eps: float, act):
    mu = mu_ref[...].astype(jnp.float32)
    sec = sec_ref[...].astype(jnp.float32)
    var, srm = _split_reps(mu, sec, rep)
    mu_tok = jnp.sum(mu, axis=-1, keepdims=True) / d
    # mean(var + (mu - mu_tok)^2) in moment form (zero-padding safe).
    spread = (jnp.sum(var + jnp.square(mu), axis=-1, keepdims=True) / d
              - jnp.square(mu_tok))
    scale = jax.lax.rsqrt(spread + eps) * gain_ref[...].astype(jnp.float32)
    mean = (mu - mu_tok) * scale + bias_ref[...].astype(jnp.float32)
    var = var * jnp.square(scale)
    if act is not None:
        mean, var = MOMENT_FNS[act](mean, var)
    mu_out_ref[...] = mean
    sec_out_ref[...] = var


@functools.partial(
    jax.jit,
    static_argnames=("rep", "d", "eps", "act", "block_rows", "interpret"),
)
def pfp_rmsnorm_pallas(mu, second, gain, *, rep: str = VAR, d: int,
                       eps: float = 1e-6, act=None,
                       block_rows: int = 256, interpret: bool = False):
    """Fused PFP RMSNorm on (rows, cols_padded). Returns (mean, second).

    Output second moment is VAR without `act`, SRM with it (activation
    contract). `d` is the true (pre-padding) feature count.
    """
    return _norm_call(_rmsnorm_kernel, (mu, second, gain), rep=rep, d=d,
                      eps=eps, act=act, block_rows=block_rows,
                      interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("rep", "d", "eps", "act", "block_rows", "interpret"),
)
def pfp_layernorm_pallas(mu, second, gain, bias, *, rep: str = VAR, d: int,
                         eps: float = 1e-6, act=None,
                         block_rows: int = 256, interpret: bool = False):
    """Fused PFP LayerNorm on (rows, cols_padded). Returns (mean, second)."""
    return _norm_call(_layernorm_kernel, (mu, second, gain, bias), rep=rep,
                      d=d, eps=eps, act=act, block_rows=block_rows,
                      interpret=interpret)


def _norm_call(kernel, args, *, rep, d, eps, act, block_rows, interpret):
    assert rep in (VAR, SRM), rep
    mu = args[0]
    m, n = mu.shape
    bm = min(block_rows, m)
    assert m % bm == 0, (m, bm)
    row_spec = pl.BlockSpec((bm, n), lambda i: (i, 0))
    vec_spec = pl.BlockSpec((1, n), lambda i: (0, 0))  # gain/bias broadcast
    in_specs = [row_spec, row_spec] + [vec_spec] * (len(args) - 2)
    fn = pl.pallas_call(
        functools.partial(kernel, rep=rep, d=d, eps=eps, act=act),
        grid=(m // bm,),
        in_specs=in_specs,
        out_specs=[row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        interpret=interpret,
    )
    return fn(*args)
