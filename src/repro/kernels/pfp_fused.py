"""Cross-op fused PFP kernel: norm -> dense -> activation in one pass.

The transformer-LM block's FFN entry is always the same three-op chain —
``rmsnorm/layernorm`` (VAR out), a bias-free ``dense`` (SRM in, VAR out),
then a moment-matched activation (SRM out). Executed separately, the
normalized (rows, K) moments round-trip through HBM twice between the
norm and the matmuls. This kernel keeps them in VMEM: each (bm, K) strip
is normalized in-register, converted to SRM exactly like
``GaussianTensor.to_srm`` (srm = var + mu^2), pushed through the Eq. 12
three-matmul joint dense with an in-body K-tile loop, and finished with
the same ``MOMENT_FNS`` epilogue the standalone activation kernel uses.

Equivalence contract (tests/test_impl_dispatch.py pins it): the fused
kernel replays the EXACT fp32 operation sequence of the unfused chain —

  * the norm math is the ``pfp_norms.py`` kernel body verbatim, with the
    reductions sliced to the same round_up(K, 128) width the standalone
    norm kernel sees (wider zero-padding would change the reduction tree);
  * the K-tile loop accumulates ``0 + dot(t0) + dot(t1) + ...`` per
    accumulator in the same order as ``pfp_dense.py``'s grid kernel, with
    ``bk`` taken from the DENSE op's schedule at the same (K, N) so the
    tiling (and therefore the fp32 add tree) is structurally identical;
  * the epilogue applies the shared elementwise ``MOMENT_FNS`` to the
    same fp32 (mean, var) values the standalone activation kernel gets.

Schedule axes searched by the autotuner: ``block_m``, ``block_n`` and the
``dims`` dimension_semantics annotation. ``block_k`` is deliberately NOT
a fused axis — it is inherited from the dense op (see above).

One backend caveat: the HLO op sequence is identical, but XLA's CPU
emitter contracts mul+add pairs into FMAs per fusion region (LLVM-level,
below HLO — ``optimization_barrier`` cannot pin it), and fusing three
kernel bodies into one necessarily changes the region boundaries. In
interpret mode the moments therefore agree to ~1 ulp per contraction
(<= 1e-3 relative end-to-end) rather than bitwise; greedy tokens and the
cache-miss fallback (which runs the real unfused chain) remain exact.
The barriers below still pin every HLO-level rounding point to the
unfused chain's HBM boundaries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gaussian import SRM, VAR
from repro.kernels.pfp_activations import MOMENT_FNS
from repro.kernels.pfp_dense import _compiler_params
from repro.kernels.pfp_norms import _split_reps


def _norm_dense_act_kernel(
    mu_ref, sec_ref, gain_ref, bias_ref, mu_w_ref, srm_w_ref,
    mu_out_ref, srm_out_ref,
    *, norm: str, rep: str, d: int, k128: int, eps: float, act: str,
    bk: int, nk: int,
):
    """One (i, j) grid step: full-K norm + SRM convert + tiled joint dense
    + activation epilogue, all in fp32 registers."""
    mu = mu_ref[...].astype(jnp.float32)          # (bm, kp)
    sec = sec_ref[...].astype(jnp.float32)
    var, srm = _split_reps(mu, sec, rep)
    gain = gain_ref[...].astype(jnp.float32)
    # Reductions run over the exact round_up(K, 128) window the standalone
    # norm kernel sees; any further (block_k-multiple) padding is zeros and
    # must stay OUT of the reduction tree to keep the fp32 sums bit-equal.
    if norm == "rmsnorm":
        nrm = jax.lax.rsqrt(
            jnp.sum(srm[:, :k128], axis=-1, keepdims=True) / d + eps)
        scale = nrm * gain
        h_mu = mu * scale
        h_var = var * jnp.square(scale)
    else:  # layernorm — pfp_norms._layernorm_kernel verbatim
        mu_tok = jnp.sum(mu[:, :k128], axis=-1, keepdims=True) / d
        spread = (jnp.sum(var[:, :k128] + jnp.square(mu[:, :k128]),
                          axis=-1, keepdims=True) / d
                  - jnp.square(mu_tok))
        scale = jax.lax.rsqrt(spread + eps) * gain
        h_mu = (mu - mu_tok) * scale + bias_ref[...].astype(jnp.float32)
        h_var = var * jnp.square(scale)
    # The unfused chain rounds the norm output to fp32 at the HBM
    # boundary before to_srm / the dense consume it; inside one kernel
    # body XLA would instead FMA-contract  var*scale^2 + h_mu^2  and
    # produce different bits. The barrier pins the same rounding points
    # the split kernels have (it only blocks instruction merging — the
    # values never leave VMEM).
    h_mu, h_var = jax.lax.optimization_barrier((h_mu, h_var))
    # GaussianTensor.to_srm on a VAR tensor: second + mean^2. Padded
    # columns have gain == 0, so h_mu == h_var == h_srm == 0 there and the
    # dense accumulation below matches the zero-padded unfused operands.
    h_srm = h_var + jnp.square(h_mu)

    # Joint PFP dense (Eq. 12), same three-dot-per-tile order as
    # pfp_dense._dense_kernel so the fp32 accumulation is bit-identical.
    shape = mu_out_ref.shape
    mu_acc = jnp.zeros(shape, jnp.float32)
    var_acc = jnp.zeros(shape, jnp.float32)
    musq_acc = jnp.zeros(shape, jnp.float32)
    for t in range(nk):
        sl = slice(t * bk, (t + 1) * bk)
        xm = h_mu[:, sl]
        wm = mu_w_ref[sl, :]
        mu_acc = mu_acc + jnp.dot(xm, wm,
                                  preferred_element_type=jnp.float32)
        var_acc = var_acc + jnp.dot(h_srm[:, sl], srm_w_ref[sl, :],
                                    preferred_element_type=jnp.float32)
        musq_acc = musq_acc + jnp.dot(jnp.square(xm), jnp.square(wm),
                                      preferred_element_type=jnp.float32)
    y_var = var_acc - musq_acc
    # Second HBM-boundary rounding point of the unfused chain: the dense
    # kernel writes (mean, var) out before the activation kernel reads it.
    mu_acc, y_var = jax.lax.optimization_barrier((mu_acc, y_var))

    # Shared moment-matched activation epilogue: VAR -> SRM, elementwise,
    # so tile geometry can't perturb it.
    a_mu, a_srm = MOMENT_FNS[act](mu_acc, y_var)
    mu_out_ref[...] = a_mu
    srm_out_ref[...] = a_srm


@functools.partial(
    jax.jit,
    static_argnames=("norm", "rep", "d", "k128", "eps", "act",
                     "block_m", "block_n", "block_k", "dims", "interpret"),
)
def pfp_norm_dense_act_pallas(
    mu, second, gain, bias, mu_w, srm_w,
    *,
    norm: str = "rmsnorm",
    rep: str = VAR,
    d: int,
    k128: int,
    eps: float = 1e-6,
    act: str = "silu",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    dims: str = "parallel",
    interpret: bool = False,
):
    """Fused norm+dense+activation on padded 2D operands.

    mu/second (M, Kp) x mu_w/srm_w (Kp, N) -> (mean, SRM) (M, N) fp32.
    ``d`` is the true feature count, ``k128`` the standalone norm kernel's
    round_up(d, 128) reduction width (Kp may exceed it to reach a block_k
    multiple — those columns are zero). ``bias`` is layernorm's shift
    (pass zeros for rmsnorm; the dense bias is not fused — the dispatch
    fusion pass only fires on bias-free dense).
    """
    assert norm in ("rmsnorm", "layernorm"), norm
    assert rep in (VAR, SRM), rep
    m, kp = mu.shape
    _, n = mu_w.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, kp)
    assert m % bm == 0 and n % bn == 0 and kp % bk == 0, (m, n, kp, bm, bn, bk)
    assert k128 <= kp, (k128, kp)
    nk = kp // bk

    row_spec = pl.BlockSpec((bm, kp), lambda i, j: (i, 0))
    vec_spec = pl.BlockSpec((1, kp), lambda i, j: (0, 0))
    w_spec = pl.BlockSpec((kp, bn), lambda i, j: (0, j))
    out_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))

    common = dict(
        grid=(m // bm, n // bn),
        in_specs=[row_spec, row_spec, vec_spec, vec_spec, w_spec, w_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        interpret=interpret,
    )
    params = _compiler_params((dims, dims))
    if params is not None and not interpret:
        common["compiler_params"] = params
    fn = pl.pallas_call(
        functools.partial(
            _norm_dense_act_kernel, norm=norm, rep=rep, d=d, k128=k128,
            eps=eps, act=act, bk=bk, nk=nk),
        **common,
    )
    return fn(mu, second, gain, bias, mu_w, srm_w)
