"""Fused moment-matched activation Pallas kernels (VPU elementwise).

The paper observes (Fig. 6, Table 4) that "trivial" operators like ReLU
become hot under PFP: Eq. 8/9 needs erf + exp per element, twice. On TPU
these are VPU transcendentals; the kernel fuses the mean and SRM outputs so
(mu, var) tiles are read from HBM once and both outputs are written once —
the joint-operator principle applied to the elementwise case.

GELU/SiLU use unrolled Gauss–Hermite quadrature: NODES fused multiply-adds
per element with compile-time constants — no (.., nodes) intermediate is
materialized, which keeps VMEM pressure at 2 tiles in / 2 tiles out.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.gaussian import VAR_EPS

_SQRT_2 = math.sqrt(2.0)
_SQRT_2PI = math.sqrt(2.0 * math.pi)


def _relu_moments(mu, var):
    safe_var = jnp.maximum(var, VAR_EPS)
    std = jnp.sqrt(safe_var)
    cdf = 0.5 * (1.0 + jax.lax.erf(mu / (std * _SQRT_2)))
    pdf = std * jnp.exp(-0.5 * jnp.square(mu) / safe_var) / _SQRT_2PI
    mean_out = mu * cdf + pdf                                   # Eq. (8)
    srm_out = (safe_var + jnp.square(mu)) * cdf + mu * pdf      # Eq. (9)
    det = var <= VAR_EPS
    det_mean = jnp.maximum(mu, 0.0)
    mean_out = jnp.where(det, det_mean, mean_out)
    srm_out = jnp.where(det, jnp.square(det_mean), jnp.maximum(srm_out, 0.0))
    return mean_out, srm_out


def _make_gh_moments(fn, num_nodes: int):
    nodes, weights = np.polynomial.hermite.hermgauss(num_nodes)
    weights = weights / math.sqrt(math.pi)

    def moments(mu, var):
        scale = jnp.sqrt(jnp.maximum(var, 0.0)) * _SQRT_2
        acc_m = jnp.zeros_like(mu)
        acc_s = jnp.zeros_like(mu)
        for xi, wi in zip(nodes, weights):  # unrolled: NODES FMAs on the VPU
            fx = fn(mu + scale * float(xi))
            acc_m = acc_m + float(wi) * fx
            acc_s = acc_s + float(wi) * jnp.square(fx)
        return acc_m, acc_s

    return moments


# In-kernel moment-matching bodies: fn(mu, var) -> (mean, srm) in fp32.
# Shared with the fused norm kernels (pfp_norms.py activation epilogues).
MOMENT_FNS = {
    "relu": _relu_moments,
    "gelu": _make_gh_moments(jax.nn.gelu, 8),
    "silu": _make_gh_moments(jax.nn.silu, 8),
    "tanh": _make_gh_moments(jnp.tanh, 8),
    "sigmoid": _make_gh_moments(jax.nn.sigmoid, 8),
}


def _make_kernel(kind: str):
    def kernel(mu_ref, var_ref, mu_out_ref, srm_out_ref):
        m, s = MOMENT_FNS[kind](
            mu_ref[...].astype(jnp.float32), var_ref[...].astype(jnp.float32)
        )
        mu_out_ref[...] = m
        srm_out_ref[...] = s

    return kernel


_KERNELS = {kind: _make_kernel(kind) for kind in MOMENT_FNS}


def _glu_product_kernel(mu_a_ref, srm_a_ref, mu_b_ref, srm_b_ref,
                        mu_out_ref, srm_out_ref):
    """Exact product of independent Gaussians in SRM representation.

    The representation-contract payoff (paper §5): two elementwise
    multiplies per element, one fused HBM round-trip for both outputs.
    """
    mu_out_ref[...] = (mu_a_ref[...].astype(jnp.float32)
                       * mu_b_ref[...].astype(jnp.float32))
    srm_out_ref[...] = (srm_a_ref[...].astype(jnp.float32)
                        * srm_b_ref[...].astype(jnp.float32))


@functools.partial(
    jax.jit, static_argnames=("kind", "block_rows", "block_cols", "interpret")
)
def pfp_activation_pallas(
    mu,
    var,
    *,
    kind: str = "relu",
    block_rows: int = 256,
    block_cols: int = 512,
    interpret: bool = False,
):
    """Fused (mu, var) -> (mu, srm) activation. Expects 2D padded input."""
    m, n = mu.shape
    bm, bn = min(block_rows, m), min(block_cols, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    fn = pl.pallas_call(
        _KERNELS[kind],
        grid=(m // bm, n // bn),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        interpret=interpret,
    )
    return fn(mu, var)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "block_cols", "interpret")
)
def pfp_glu_pallas(
    mu_a,
    srm_a,
    mu_b,
    srm_b,
    *,
    block_rows: int = 256,
    block_cols: int = 512,
    interpret: bool = False,
):
    """Fused SRM gated product: (mu, srm) x (mu, srm) -> (mu, srm), 2D padded."""
    m, n = mu_a.shape
    bm, bn = min(block_rows, m), min(block_cols, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    fn = pl.pallas_call(
        _glu_product_kernel,
        grid=(m // bm, n // bn),
        in_specs=[spec] * 4,
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        interpret=interpret,
    )
    return fn(mu_a, srm_a, mu_b, srm_b)
