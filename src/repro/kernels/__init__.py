"""PFP Pallas TPU kernels — the compute hot-spots the paper optimizes.

Paper (TVM/ARM)                      ->  here (Pallas/TPU)
  joint PFP dense operator               pfp_dense.py   (3 MXU matmuls/tile)
  PFP ReLU / moment-matched act          pfp_activations.py (VPU, fused mu+srm)
  vectorized Max Pool k=2                pfp_maxpool.py (Clark tournament)
  — (beyond paper: transformers)         pfp_attention.py (flash-style joint
                                          mean/variance online softmax)

`ops.py` holds the jit'd public wrappers; `ref.py` the pure-jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
