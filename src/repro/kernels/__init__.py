"""PFP Pallas TPU kernels — the compute hot-spots the paper optimizes.

Paper (TVM/ARM)                      ->  here (Pallas/TPU)
  joint PFP dense operator               pfp_dense.py   (3 MXU matmuls/tile)
  PFP ReLU / moment-matched act          pfp_activations.py (VPU, fused mu+srm)
  vectorized Max Pool k=2                pfp_maxpool.py (Clark tournament)
  — (beyond paper: transformers)         pfp_attention.py (flash-style joint
                                          mean/variance online softmax)
                                         pfp_norms.py (fused RMSNorm/LayerNorm
                                          with optional activation epilogue)
                                         pfp_activations.py::pfp_glu_pallas
                                          (SRM gated product)

`ops.py` holds the jit'd public wrappers (shape plumbing, padding,
interpret-mode fallback off-TPU); `ref.py` the pure-jnp oracles every
kernel is validated against.

Models do NOT import this package directly: every PFP op resolves through
the impl-dispatch registry in ``repro.core.dispatch``, where each op is
registered once with its ``'xla'`` (pure-jnp / pjit graph) and
``'kernel'`` (these Pallas wrappers) implementation. ``Context(impl=...)``
— or ``repro.core.dispatch.set_default_impl`` — flips an entire model
forward between the two stacks; the parity suite
(tests/test_impl_dispatch.py) pins the two implementations of every op to
each other, and ``ref.py``/tests/test_kernels.py pin the kernels to the
Monte-Carlo-validated moment algebra underneath.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
