"""Specialized vectorized PFP max-pool kernel (k=2, stride 2) — Clark maxes.

TPU adaptation of the paper's §6.2 "Vectorized Max Pool k=2": instead of a
generic reduction (slow in TVM and untunable, Table 3), the wrapper slices
the NHWC input into its four 2x2 phases once (XLA strided slices), and the
kernel runs a pure-elementwise tournament of three Clark pairwise maxes —
fully VPU-vectorized with zero shuffles inside the kernel.

Consumes VAR, emits VAR (paper: pooling layers keep variances).

(block_rows, block_cols) tile the flattened (N*Ho*Wo, C) phase arrays;
the autotuner (repro.tuning) overrides the defaults through
`ops.pfp_maxpool2d`'s schedule argument.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gaussian import VAR_EPS

_SQRT_2 = math.sqrt(2.0)
_SQRT_2PI = math.sqrt(2.0 * math.pi)


def _clark(mu_a, var_a, mu_b, var_b):
    theta = jnp.sqrt(jnp.maximum(var_a + var_b, VAR_EPS))
    alpha = (mu_a - mu_b) / theta
    cdf_a = 0.5 * (1.0 + jax.lax.erf(alpha / _SQRT_2))
    cdf_b = 1.0 - cdf_a
    pdf = jnp.exp(-0.5 * jnp.square(alpha)) / _SQRT_2PI
    mean = mu_a * cdf_a + mu_b * cdf_b + theta * pdf
    srm = (
        (jnp.square(mu_a) + var_a) * cdf_a
        + (jnp.square(mu_b) + var_b) * cdf_b
        + (mu_a + mu_b) * theta * pdf
    )
    det = (var_a + var_b) <= VAR_EPS
    det_mean = jnp.maximum(mu_a, mu_b)
    mean = jnp.where(det, det_mean, mean)
    var = jnp.where(det, 0.0, jnp.maximum(srm - jnp.square(mean), 0.0))
    return mean, var


def _pool_kernel(m00, v00, m01, v01, m10, v10, m11, v11, mu_out, var_out):
    # Tournament: reduce the two W-phases, then the two H-phases.
    mw0, vw0 = _clark(m00[...], v00[...], m01[...], v01[...])
    mw1, vw1 = _clark(m10[...], v10[...], m11[...], v11[...])
    mean, var = _clark(mw0, vw0, mw1, vw1)
    mu_out[...] = mean
    var_out[...] = var


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def pfp_maxpool2d_pallas(mu, var, *, block_rows: int = 256,
                         block_cols: int = 128, interpret: bool = False):
    """2x2/2 PFP max pool on NHWC (mu, var). Returns NHoWoC (mu, var)."""
    n, h, w, c = mu.shape
    assert h % 2 == 0 and w % 2 == 0, (h, w)
    ho, wo = h // 2, w // 2

    def phases(a):
        return (
            a[:, 0::2, 0::2, :], a[:, 0::2, 1::2, :],
            a[:, 1::2, 0::2, :], a[:, 1::2, 1::2, :],
        )

    def flat(a):
        return a.reshape(n * ho * wo, c)

    rows = n * ho * wo
    args = [flat(p).astype(jnp.float32) for pair in zip(phases(mu), phases(var)) for p in pair]

    bm = min(block_rows, rows)
    bn = min(block_cols, c)
    # Pad to block multiples (tiny images in the paper's models).
    pm = (-rows) % bm
    pn = (-c) % bn
    if pm or pn:
        args = [jnp.pad(a, ((0, pm), (0, pn))) for a in args]
    rows_p, c_p = rows + pm, c + pn

    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    fn = pl.pallas_call(
        _pool_kernel,
        grid=(rows_p // bm, c_p // bn),
        in_specs=[spec] * 8,
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((rows_p, c_p), jnp.float32),
            jax.ShapeDtypeStruct((rows_p, c_p), jnp.float32),
        ],
        interpret=interpret,
    )
    mu_o, var_o = fn(*args)
    mu_o = mu_o[:rows, :c].reshape(n, ho, wo, c)
    var_o = var_o[:rows, :c].reshape(n, ho, wo, c)
    return mu_o, var_o
