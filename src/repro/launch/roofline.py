"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh):
    compute    = HLO_FLOPs / (chips * 197e12)         [bf16 MXU peak, v5e]
    memory     = HLO_bytes / (chips * 819e9)          [HBM bandwidth]
    collective = collective_bytes / (chips * links * 50e9)   [ICI]

HLO_FLOPs / bytes come from compiled.cost_analysis(). Collective bytes are
NOT in cost_analysis: we parse the optimized HLO text and sum operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction. Cost/collective numbers from the CPU-lowered
SPMD module are per-device programs — the parser reports per-device bytes.
"""
from __future__ import annotations

import re
from typing import Dict

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (we count 1 effective link —
                             # conservative; axis-specific links noted in
                             # EXPERIMENTS.md)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = (f32[16,128]{1,0}, f32[8]{0}) all-gather(...)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(.*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes per collective kind (per-device program).

    '-done' ops are skipped so async start/done pairs count once.
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1]
        for kind in _COLLECTIVES:
            idx = rhs.find(kind + "(")
            if idx < 0:
                idx2 = rhs.find(kind + "-start(")
                if idx2 < 0:
                    continue
                idx = idx2
            # shape expression sits between '=' and the op name
            out[kind] += _shape_bytes(rhs[:idx])
            break
    return out


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float, *, links: int = 1) -> dict:
    compute = flops_per_device / PEAK_FLOPS
    memory = bytes_per_device / HBM_BW
    collective = coll_bytes_per_device / (ICI_BW * links)
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    total = max(compute, memory, collective)
    terms["step_time_lower_bound_s"] = total
    terms["roofline_fraction"] = compute / total if total > 0 else 0.0
    return terms


def model_flops(meta: dict, shape_kind: str, seq_len: int, global_batch: int,
                new_tokens: int = 1) -> float:
    """MODEL_FLOPS = 6 N D (train) or 2 N D (inference), N = active params."""
    n = meta["active_params"]
    if shape_kind == "train":
        d = seq_len * global_batch
        return 6.0 * n * d
    if shape_kind == "prefill":
        d = seq_len * global_batch
        return 2.0 * n * d
    d = new_tokens * global_batch
    return 2.0 * n * d
