"""Production mesh construction.

Single pod : (16, 16)    axes ('data', 'model')   = 256 chips (v5e pod)
Multi pod  : (2, 16, 16) axes ('pod', 'data', 'model') = 512 chips

Axis roles:
  pod   — pure data parallelism across pods (slow DCN links; gradients
          reduced hierarchically, parameters NOT sharded across pods)
  data  — FSDP: batch AND parameter/optimizer sharding (fast ICI)
  model — TP/EP/SP: attention heads & FFN width, experts, long-seq caches

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    dev = np.array(devices[:need]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests (e.g. (2, 4) on 8 host devices)."""
    need = int(np.prod(shape))
    dev = np.array(jax.devices()[:need]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def dp_axes(mesh) -> tuple:
    """Axes that shard the batch (pure DP + FSDP)."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def fsdp_axis(mesh) -> str:
    """Axis that shards parameters/optimizer state (within-pod only)."""
    return "data"


def axis_size(mesh, name) -> int:
    return mesh.shape[name]
