"""Distributed training driver: the program the dry-run lowers, executed.

On real hardware each host runs this under `jax.distributed.initialize()`;
on this container it runs the same code path on a small host-device mesh
(--devices N sets XLA_FLAGS before jax init). Demonstrates the full
production loop: sharded params/optimizer, per-host data shards,
checkpoint/restart (elastic), straggler monitoring.

Usage:
  PYTHONPATH=src python -m repro.launch.train --devices 8 --mesh 4,2 \
      --arch granite-8b --reduced --steps 20
"""
import argparse
import os
import sys


def _early_flags():
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--devices", type=int, default=8)
    args, _ = ap.parse_known_args()
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")


_early_flags()

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.bayes.variational import KLSchedule  # noqa: E402
from repro.configs import get_config, reduced_config  # noqa: E402
from repro.data.tokens import TokenPipeline  # noqa: E402
from repro.launch import sharding as shlib  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.training.checkpoint import CheckpointManager  # noqa: E402
from repro.training.fault_tolerance import StepMonitor  # noqa: E402
from repro.training.optimizer import Adam, cosine_schedule  # noqa: E402
from repro.training.train_loop import (TrainState, init_train_state,  # noqa: E402
                                       make_svi_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--mesh", default="4,2", help="data,model")
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/pfp_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    dims = tuple(int(x) for x in args.mesh.split(","))
    mesh = make_mesh(dims, ("data", "model"))
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    print(f"mesh={dict(mesh.shape)} arch={cfg.name} "
          f"params~{cfg.param_count() / 1e6:.1f}M")

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = Adam(learning_rate=cosine_schedule(1e-3, 5, args.steps),
               clip_norm=1.0)
    state = init_train_state(params, opt)

    # Shard the train state onto the mesh (same rules as the dry-run).
    p_sh = shlib.params_shardings(
        jax.eval_shape(lambda: params), mesh)
    state_sh = TrainState(
        params=p_sh,
        opt_state=type(state.opt_state)(
            step=shlib.replicated(mesh), m=p_sh, v=p_sh),
        step=shlib.replicated(mesh))
    state = jax.device_put(state, state_sh)

    def fwd(p, batch, ctx):
        logits, aux, _ = lm.forward(p, cfg, batch, ctx)
        return logits, aux

    step_fn = jax.jit(
        make_svi_train_step(fwd, opt,
                            num_data=args.batch * args.seq * args.steps,
                            kl_schedule=KLSchedule(0.25, args.steps)),
        in_shardings=(state_sh,
                      shlib.batch_shardings(
                          {"tokens": jax.ShapeDtypeStruct(
                              (args.batch, args.seq), jnp.int32),
                           "targets": jax.ShapeDtypeStruct(
                              (args.batch, args.seq), jnp.int32)}, mesh),
                      shlib.replicated(mesh)),
        # Pin the output state to the input sharding: the state feeds back
        # into the next step (donated), so XLA must not re-shard it.
        out_shardings=(state_sh, None),
        donate_argnums=(0,))

    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch)
    mgr = CheckpointManager(args.ckpt_dir)
    monitor = StepMonitor()
    start = 0
    if args.resume and mgr.latest_step() is not None:
        state, start = mgr.restore(state, shardings=state_sh)
        print(f"resumed from step {start} (elastic onto {dims} mesh)")

    with mesh:
        for i in range(start, args.steps):
            t0 = time.perf_counter()
            batch = jax.tree_util.tree_map(jnp.asarray, pipe.batch(i))
            state, m = step_fn(state, batch, jax.random.PRNGKey(i))
            dt = time.perf_counter() - t0
            verdict = monitor.record(i, dt)
            if i % 5 == 0 or verdict == "straggle":
                print(f"step {i:4d} loss={float(m['loss']):.3f} "
                      f"nll={float(m['nll']):.3f} {dt * 1e3:.0f}ms [{verdict}]")
            if (i + 1) % 10 == 0:
                mgr.save(i + 1, state)
    mgr.wait()
    print("done; latest checkpoint:", mgr.latest_step())
    return 0


if __name__ == "__main__":
    sys.exit(main())
