"""Distributed PFP serving driver: prefill + uncertainty-aware decode on a
(data, model) mesh — the executed version of the decode_* dry-run cells.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --devices 8 --mesh 2,4 \
      --arch granite-8b --reduced --tokens 8
"""
import argparse
import os
import sys


def _early_flags():
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--devices", type=int, default=8)
    args, _ = ap.parse_known_args()
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")


_early_flags()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.bayes.convert import svi_to_pfp  # noqa: E402
from repro.configs import get_config, reduced_config  # noqa: E402
from repro.launch import sharding as shlib  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.nn.module import Context  # noqa: E402
from repro.core.modes import Mode  # noqa: E402
from repro.serving.decode import uncertainty_decode  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--mesh", default="2,4")
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    args = ap.parse_args()

    dims = tuple(int(x) for x in args.mesh.split(","))
    mesh = make_mesh(dims, ("data", "model"))
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    max_len = args.prompt_len + args.tokens

    params = svi_to_pfp(lm.init_params(cfg, jax.random.PRNGKey(0)))
    p_sh = shlib.params_shardings(jax.eval_shape(lambda: params), mesh,
                                  serve=True)
    params = jax.device_put(params, p_sh)
    ctx = Context(mode=Mode.PFP)

    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    with mesh:
        last, states = lm.prefill(params, cfg, {"tokens": prompt}, ctx,
                                  max_len=max_len)
        pos = args.prompt_len
        print(f"{'step':>4s} {'tokens':24s} {'MI':>24s} abstain")
        for t in range(args.tokens):
            out = uncertainty_decode(last.mean.astype(jnp.float32),
                                     last.var.astype(jnp.float32),
                                     jax.random.PRNGKey(10 + t))
            print(f"{t:4d} {str(np.asarray(out.token)):24s} "
                  f"{str(np.asarray(out.mutual_info).round(2)):>24s} "
                  f"{np.asarray(out.abstain)}")
            dec_in = {"tokens": out.token[:, None].astype(jnp.int32),
                      "positions": jnp.full((args.batch, 1), pos, jnp.int32),
                      "cache_len": jnp.full((args.batch,), pos, jnp.int32)}
            last, states = lm.decode_step(params, cfg, dec_in, states, ctx)
            pos += 1
    print("served", args.batch, "sequences x", args.tokens,
          "tokens — one PFP pass per step (SVI would need 30x).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
