"""Distributed PFP serving driver: the continuous-batching engine on a
(data, model) mesh — the executed version of the decode_* dry-run cells.

Drives ``repro.serving.engine``: Poisson request arrivals, admission-
controlled scheduling, chunked prefill, one probabilistic forward pass per
decode step for the whole slot batch, and uncertainty routing
(continue / escalate-to-SVI / abstain). ``--impl kernel`` flips every PFP
op onto the Pallas kernels via the impl-dispatch registry (interpret mode
off-TPU).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --devices 8 --mesh 2,4 \
      --arch granite-8b --reduced --engine --tokens 8
  PYTHONPATH=src python -m repro.launch.serve --devices 2 --reduced \
      --engine --tokens 4            # CI interpret-mode smoke
  PYTHONPATH=src python -m repro.launch.serve --devices 2 --reduced \
      --impl kernel --save-schedule-db db.json   # tune + persist fleet DB
  PYTHONPATH=src python -m repro.launch.serve --devices 2 --reduced \
      --impl kernel --schedule-db db.json --expect-warm-cache  # warm start
"""
import argparse
import json
import os
import sys


def _early_flags():
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--devices", type=int, default=8)
    args, _ = ap.parse_known_args()
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")


_early_flags()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.bayes.convert import svi_to_pfp  # noqa: E402
from repro.configs import get_config, reduced_config  # noqa: E402
from repro.launch import sharding as shlib  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.obs.trace import Tracer  # noqa: E402
from repro.serving.engine import (Engine, EngineConfig, RequestScheduler,  # noqa: E402
                                  RouterConfig, SchedulerConfig,
                                  UncertaintyRouter, poisson_trace, run_load)
from repro.serving.fleet import Fleet, FleetConfig  # noqa: E402

_SUMMARY_KEYS = (
    "submitted", "rejected", "expired", "completed", "abstained",
    "escalations", "tokens_generated", "steps", "throughput_tok_s",
    "p50_latency_steps", "p99_latency_steps", "abstain_rate",
    "escalation_rate", "peak_occupancy", "final_occupancy",
)

_PAGED_KEYS = (
    "preemptions", "defrags", "peak_page_occupancy", "mean_page_occupancy",
    "mean_page_fragmentation", "final_live_pages",
)

_PREFIX_KEYS = (
    "prefix_hits", "prefix_hit_rate", "prefix_shared_pages",
    "prefill_tokens_saved", "prefill_frac_saved", "cow_copies",
    "mean_shared_pages", "final_prefix_held_pages",
)

_MOE_KEYS = (
    "moe_assignments", "moe_dropped_assignments", "moe_drop_rate",
)

_SPEC_KEYS = (
    "spec_rounds", "draft_tokens", "accepted_draft_tokens",
    "draft_acceptance_rate", "accepted_tokens_per_verify", "verify_passes",
    "decode_passes", "draft_passes", "svi_passes", "svi_passes_per_step",
    "max_svi_passes_per_step", "mean_escalation_batch",
    "pfp_passes_per_token",
)


_FLEET_KEYS = (
    "replicas", "submitted", "rejected", "expired", "finished", "completed",
    "abstained", "tokens_generated", "prefill_tokens", "steps",
    "route_prefix_hits", "route_fallbacks", "route_hit_rate",
    "route_tokens_matched", "prefix_hits", "prefix_hit_rate",
    "prefill_tokens_saved", "cow_copies", "preemptions", "requeue_overflow",
    "final_occupancy",
)

_DISAGG_KEYS = (
    "handoffs", "p50_handoff_steps", "p99_handoff_steps",
    "decode_steps_during_peer_prefill",
)


def _lane_registries(target):
    """lane -> MetricsRegistry for every telemetry owner in a serving
    stack: a single Engine, or a Fleet frontend plus each replica engine
    (a DisaggPair contributes its prefill and decode engines)."""
    if hasattr(target, "replicas"):  # Fleet
        out = {"fleet": target.metrics.registry}
        for i, rep in enumerate(target.replicas):
            if hasattr(rep, "engines"):  # DisaggPair
                out[f"r{i}.prefill"] = rep.prefill_engine.metrics.registry
                out[f"r{i}.decode"] = rep.decode_engine.metrics.registry
            else:
                out[f"r{i}"] = rep.metrics.registry
        return out
    return {"engine": target.metrics.registry}


def _profile_decode(engine):
    """One eager, per-op-fenced lockstep decode pass through the dispatch
    profiler — the live Table-4-style per-layer breakdown for the LM
    forward the engine actually serves. Runs with every slot inactive
    (paged writes redirect to the trash page; the contiguous select-merge
    discards the update), so the engine's state is untouched."""
    from repro.obs.profiler import profile_ops

    b = engine.config.slots
    feed = jnp.zeros((b, 1), jnp.int32)
    pos = jnp.zeros((b, 1), jnp.int32)
    clen = jnp.zeros(b, jnp.int32)
    active = jnp.zeros(b, bool)
    with profile_ops() as prof:
        fwd = (engine.params, feed, pos, clen, active, engine.pool.states)
        if engine.paged:
            engine.decode_fn(*fwd, engine.pool.device_table(),
                             *engine.logit_buffers)
        else:
            engine.decode_fn(*fwd, *engine.logit_buffers)
    return prof


def _export_obs(args, target, summary, tracer, profile=None):
    """Write the run's observability artifacts: JSONL + Chrome traces,
    the metrics JSON payload (run metadata + summary + every lane's
    registry snapshot), and the Prometheus text export."""
    if tracer is not None and args.trace_out:
        tracer.write_jsonl(args.trace_out)
        chrome = os.path.splitext(args.trace_out)[0] + ".chrome.json"
        tracer.write_chrome(chrome)
        print(f"trace: {len(tracer.events)} events -> {args.trace_out} "
              f"(Perfetto: {chrome})")
    regs = _lane_registries(target)
    if args.metrics_out:
        from repro.obs.runmeta import run_metadata
        payload = {
            "meta": run_metadata(),
            "summary": summary,
            "registries": {lane: reg.snapshot()
                           for lane, reg in sorted(regs.items())},
        }
        if profile is not None:
            payload["op_profile"] = profile.summary()
        os.makedirs(os.path.dirname(args.metrics_out) or ".", exist_ok=True)
        with open(args.metrics_out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"metrics: -> {args.metrics_out}")
    if args.prom_out:
        # One text exposition over every lane (lane is a label); repeated
        # HELP/TYPE headers from the per-lane exports are deduplicated.
        seen, lines = set(), []
        for lane, reg in sorted(regs.items()):
            for line in reg.to_prometheus(
                    extra_labels={"lane": lane}).splitlines():
                if line.startswith("#"):
                    if line in seen:
                        continue
                    seen.add(line)
                lines.append(line)
        os.makedirs(os.path.dirname(args.prom_out) or ".", exist_ok=True)
        with open(args.prom_out, "w") as f:
            f.write("\n".join(lines) + "\n")
        print(f"prometheus: -> {args.prom_out}")


def _run_fleet(args, cfg, params, router, sched_cfg, mesh, dims, max_len,
               build_engine, make_trace, tracer=None):
    """--replicas R: the fleet frontend path. Routed multi-replica output
    must be bit-for-bit (tokens AND MI traces) a single engine's on the
    same trace — every replica runs the baseline's pass shapes and the
    per-(uid, token) keyed sampling makes placement invisible — and every
    replica's pool must drain without a page or hold leak."""
    import numpy as np
    if args.disaggregate and (args.page_size is None
                              or not args.prefix_sharing):
        print("ERROR: --disaggregate requires --page-size and "
              "--prefix-sharing (pages hand off from the prefill engine "
              "to the decode engine through the prefix index)",
              file=sys.stderr)
        return 2
    engine_cfg = EngineConfig(
        slots=args.batch, max_len=max_len, impl=args.impl,
        compute_dtype=jnp.bfloat16, seed=args.seed,
        page_size=args.page_size, page_budget=args.page_budget,
        reserve_pages=not args.optimistic_pages,
        # a defrag inside one engine of a disaggregated pair would remap
        # the peer's tables without permuting its replay snapshot
        auto_defrag=args.page_size is not None and not args.disaggregate,
        prefix_sharing=args.prefix_sharing,
        prefix_retention_pages=args.prefix_retention,
        speculate_k=args.speculate)
    with mesh:
        fleet = Fleet(cfg, params, engine_cfg,
                      FleetConfig(replicas=args.replicas,
                                  disaggregate=args.disaggregate),
                      router=router, scheduler_config=sched_cfg, mesh=mesh,
                      tracer=tracer)
        summary = run_load(fleet, make_trace())

    profile = None
    if args.profile_ops:
        first = fleet.replicas[0]
        eng = first.decode_engine if hasattr(first, "engines") else first
        with mesh:
            profile = _profile_decode(eng)
        print("== per-op decode profile (one eager fenced pass, "
              "replica 0) ==")
        print(profile.format_table())
    _export_obs(args, fleet, summary, tracer, profile)

    mode = "disaggregated" if args.disaggregate else "replicated"
    layout = (f"paged/ps={args.page_size}" if args.page_size
              else "contiguous")
    if args.prefix_sharing:
        layout += "/prefix"
    print(f"== fleet summary ({cfg.name}, R={args.replicas} {mode}, "
          f"mesh={dims}, impl={args.impl or 'default'}, kv={layout}) ==")
    for k in _FLEET_KEYS + (_DISAGG_KEYS if args.disaggregate else ()):
        v = summary[k]
        print(f"  {k:22s} {v:.4g}" if isinstance(v, float)
              else f"  {k:22s} {v}")

    # -- per-replica drain + page/hold leak checks --------------------------
    occ = sum(r.active_slots for r in fleet.replicas)
    if occ != 0:
        print(f"ERROR: fleet leaked {occ} occupied slots after drain",
              file=sys.stderr)
        return 1
    for i, rep in enumerate(fleet.replicas):
        rep.pool.check_invariants()
        prefix = getattr(rep, "prefix", None)
        if prefix is not None:
            if prefix.pages_held > prefix.retention_pages:
                print(f"ERROR: replica {i} prefix index holds "
                      f"{prefix.pages_held} pages beyond its retention of "
                      f"{prefix.retention_pages}", file=sys.stderr)
                return 1
            prefix.check_invariants(rep.pool)
        if args.page_size is not None:
            pool = rep.pool
            leaked = [p for p in range(1, pool.num_pages)
                      if pool.page_ref[p] != pool.external_holds[p]]
            if leaked:
                print(f"ERROR: replica {i} page/hold leak on pages "
                      f"{leaked[:8]} ({len(leaked)} total) after drain",
                      file=sys.stderr)
                return 1

    if args.expect_route_hits is not None:
        if summary["route_prefix_hits"] == 0 or \
                summary["route_hit_rate"] < args.expect_route_hits:
            print("ERROR: --expect-route-hits: "
                  f"{summary['route_prefix_hits']} prefix routes at "
                  f"hit-rate {summary['route_hit_rate']:.3f} "
                  f"(floor {args.expect_route_hits})", file=sys.stderr)
            return 1
    if args.disaggregate and summary["handoffs"] == 0:
        print("ERROR: --disaggregate but no prefill->decode handoff "
              "completed (trace drained without disaggregation engaging)",
              file=sys.stderr)
        return 1

    # -- bit-for-bit parity with a single engine ----------------------------
    # The baseline reuses the fleet's exact engine_cfg (NOT build_engine's,
    # which re-enables auto_defrag): with an identical pass signature it
    # shares the replicas' compiled executables, so the comparison can
    # only surface routing/handoff bugs, never compilation variance.
    assert build_engine is not None  # single-engine path's builder, unused
    with mesh:
        single = Engine(cfg, params, engine_cfg, router=router,
                        scheduler=RequestScheduler(sched_cfg,
                                                   max_len=max_len),
                        mesh=mesh)
        run_load(single, make_trace())
    out = lambda reqs: {r.uid: (list(r.generated),  # noqa: E731
                                [float(m) for m in r.mi_trace],
                                r.finish_reason) for r in reqs}
    got, want = out(fleet.finished), out(single.finished)
    if got != want:
        diff = sorted(u for u in set(got) | set(want)
                      if got.get(u) != want.get(u))
        print("ERROR: routed fleet output diverged from the single-engine "
              f"baseline on uids {diff[:8]} (tokens and MI traces must be "
              "bit-for-bit identical)", file=sys.stderr)
        return 1
    assert np is not None  # imported for parity-debug sessions
    print(f"fleet served {summary['completed']} requests "
          f"({summary['tokens_generated']} tokens) across {args.replicas} "
          "replicas — bit-for-bit the single-engine stream, "
          f"{summary['route_prefix_hits']} of them routed to a cached "
          "prefix.")
    return 0


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--mesh", default=None,
                    help="data,model dims (default: 1,<devices>)")
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full-size", dest="reduced", action="store_false",
                    help="serve the full-size config instead of the "
                         "reduced CPU-smoke one")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (the continuous batch size)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8,
                    help="max new tokens per request")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate (requests per engine step)")
    ap.add_argument("--engine", action="store_true", default=True,
                    help="no-op compatibility flag: the continuous-batching "
                         "engine is the only serving path (the pre-engine "
                         "lockstep demo loop was removed)")
    ap.add_argument("--impl", default=None, choices=["xla", "kernel"],
                    help="PFP operator implementation (core/dispatch.py)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="paged Gaussian KV-cache page size (rows per "
                         "page); default: contiguous per-slot layout")
    ap.add_argument("--page-budget", type=int, default=None,
                    help="usable pages in the pool (default: "
                         "slots * ceil(max_len / page_size))")
    ap.add_argument("--optimistic-pages", action="store_true",
                    help="admit on prompt pages only and claim decode "
                         "pages on demand (may preempt) instead of "
                         "reserving the full prompt+generation need")
    ap.add_argument("--expect-defrag", action="store_true",
                    help="exit nonzero unless the run performed at least "
                         "one page defrag (CI: prove multi-page churn)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="refcounted copy-on-write prefix sharing: index "
                         "finished lineages' pages and map them into new "
                         "requests sharing a prompt prefix (paged only)")
    ap.add_argument("--prefix-retention", type=int, default=None,
                    help="max pages the prefix index may hold for finished "
                         "lineages (default: the whole page budget)")
    ap.add_argument("--common-prefix", type=int, default=0,
                    help="overwrite the first N tokens of every generated "
                         "prompt with one fixed system prefix, so the "
                         "trace exercises prefix sharing")
    ap.add_argument("--expect-prefix-hits", action="store_true",
                    help="exit nonzero unless at least one admission "
                         "mapped shared prefix pages (CI smoke)")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="uncertainty-speculative decoding: draft K tokens "
                         "per slot with a mean-only pass and verify the "
                         "block with ONE chunked PFP pass (paged only); "
                         "the run is checked bit-for-bit against a plain "
                         "engine on the same trace")
    ap.add_argument("--expect-moe-drop", action="store_true",
                    help="exit nonzero unless the run recorded MoE routing "
                         "accounting (moe_assignments > 0) and a finite "
                         "drop rate — CI: prove the aux-loss-free decode "
                         "path surfaces expert-capacity drops (moe only)")
    ap.add_argument("--expect-accept-rate", type=float, default=None,
                    metavar="R",
                    help="exit nonzero if the draft acceptance rate falls "
                         "below R (CI: prove speculation actually "
                         "amortizes verify passes)")
    ap.add_argument("--replicas", type=int, default=1, metavar="R",
                    help="serve through a fleet of R data-parallel replica "
                         "engines behind a prefix-routing frontend; the "
                         "routed output is checked bit-for-bit against a "
                         "single-engine baseline on the same trace")
    ap.add_argument("--disaggregate", action="store_true",
                    help="split each replica into a prefill engine and a "
                         "decode engine over one shared page pool (needs "
                         "--page-size and --prefix-sharing): prompts "
                         "prefill on the prefill engine and the pages hand "
                         "off through the prefix index, so decode "
                         "admission never waits behind a long prompt")
    ap.add_argument("--expect-route-hits", type=float, default=None,
                    nargs="?", const=0.0, metavar="RATE",
                    help="exit nonzero unless at least one request was "
                         "routed to a replica's cached prefix (with a "
                         "value: unless the routing prefix hit-rate is "
                         ">= RATE)")
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--mi-continue", type=float, default=0.5)
    ap.add_argument("--mi-abstain", type=float, default=3.0)
    ap.add_argument("--escalate-samples", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    # -- observability ------------------------------------------------------
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the deterministic request trace as JSONL "
                         "to PATH and a Perfetto/chrome://tracing view to "
                         "PATH's stem + '.chrome.json'")
    ap.add_argument("--trace-wall", action="store_true",
                    help="annotate every trace event with wall-clock "
                         "seconds (strippable; the step-keyed trace stays "
                         "deterministic without it)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics payload (run metadata, the "
                         "summary, every lane's registry snapshot) as JSON")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="write every lane's registry in Prometheus text "
                         "exposition format (lane as a label)")
    ap.add_argument("--profile-ops", action="store_true",
                    help="after the run, time ONE eager per-op-fenced "
                         "decode pass at the dispatch registry and print "
                         "the per-layer breakdown (paper Table 4, live)")
    # -- autoscheduler / warm-start fleet schedule DB -----------------------
    ap.add_argument("--schedule-db", default=None, metavar="PATH",
                    help="preload a persistent tuned-schedule DB "
                         "(repro.tuning fleet cache) before building the "
                         "engine, so every compile starts warm — no "
                         "schedule search on the serving hot path")
    ap.add_argument("--save-schedule-db", default=None, metavar="PATH",
                    help="record every (op, shape, dtype) the run consults, "
                         "tune any missing entry (cost-model 'rank' mode), "
                         "and merge-save the DB to PATH (atomic write; "
                         "concurrent replica writers lose nothing)")
    ap.add_argument("--expect-warm-cache", action="store_true",
                    help="exit nonzero if any tuning-cache consult missed "
                         "during the run (CI: prove a preloaded "
                         "--schedule-db covers the model's full shape set)")
    ap.add_argument("--fuse-ops", action="store_true",
                    help="enable the dispatch fusion pass: eligible "
                         "norm->dense->activation chains run as one fused "
                         "Pallas kernel when a tuned norm_dense_act "
                         "schedule is cached (falls back to the unfused "
                         "chain otherwise)")
    return ap.parse_args()


def _serve(args):
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
    else:
        dims = (1, args.devices)
    mesh = make_mesh(dims, ("data", "model"))
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    max_len = args.prompt_len + args.tokens

    params = svi_to_pfp(lm.init_params(cfg, jax.random.PRNGKey(args.seed)))
    p_sh = shlib.params_shardings(jax.eval_shape(lambda: params), mesh,
                                  serve=True)
    params = jax.device_put(params, p_sh)

    router = UncertaintyRouter(
        cfg, RouterConfig(mi_continue=args.mi_continue,
                          mi_abstain=args.mi_abstain,
                          escalate_samples=args.escalate_samples),
        impl=args.impl)
    sched_cfg = SchedulerConfig(prefill_chunk=args.prefill_chunk,
                                prefill_budget=2 * args.prefill_chunk)
    scheduler = RequestScheduler(sched_cfg, max_len=max_len)
    def make_trace():
        # Regenerable: run_load mutates the Request objects, so the
        # speculative parity check below needs a fresh copy per engine.
        trace = poisson_trace(
            args.requests, args.rate, vocab_size=cfg.vocab_size,
            seed=args.seed,
            prompt_len=(max(2, args.prompt_len // 2), args.prompt_len),
            max_new_tokens=(max(1, args.tokens // 2), args.tokens))
        if args.common_prefix:
            # one fixed system prefix across the whole trace
            # (deterministic), so requests share their leading pages once
            # a donor finishes
            import numpy as np
            system = (np.arange(args.common_prefix, dtype=np.int32)
                      % cfg.vocab_size)
            for r in trace:
                n = min(args.common_prefix, len(r.prompt) - 1)
                r.prompt[:n] = system[:n]
        return trace

    tracer = Tracer(wall=args.trace_wall) if args.trace_out else None

    def build_engine(speculate_k):
        return Engine(
            cfg, params,
            # bf16 activations, mirroring the decode_* dry-run programs
            # (serving/decode.py) whose executed version this driver is
            EngineConfig(slots=args.batch, max_len=max_len, impl=args.impl,
                         compute_dtype=jnp.bfloat16, seed=args.seed,
                         page_size=args.page_size,
                         page_budget=args.page_budget,
                         reserve_pages=not args.optimistic_pages,
                         auto_defrag=args.page_size is not None,
                         prefix_sharing=args.prefix_sharing,
                         prefix_retention_pages=args.prefix_retention,
                         speculate_k=speculate_k),
            router=router, scheduler=scheduler, mesh=mesh, tracer=tracer)

    if args.replicas > 1:
        return _run_fleet(args, cfg, params, router, sched_cfg, mesh, dims,
                          max_len, build_engine, make_trace, tracer)

    with mesh:
        engine = build_engine(args.speculate)
        summary = run_load(engine, make_trace())

    profile = None
    if args.profile_ops:
        with mesh:
            profile = _profile_decode(engine)
        print("== per-op decode profile (one eager fenced pass) ==")
        print(profile.format_table())
    _export_obs(args, engine, summary, tracer, profile)

    layout = (f"paged/ps={args.page_size}" if args.page_size else "contiguous")
    if args.prefix_sharing:
        layout += "/prefix"
    if args.speculate:
        layout += f"/spec-k{args.speculate}"
    print(f"== engine summary ({cfg.name}, mesh={dims}, "
          f"impl={args.impl or 'default'}, kv={layout}) ==")
    keys = _SUMMARY_KEYS + (_MOE_KEYS if cfg.family == "moe" else ()) + \
        (_PAGED_KEYS if args.page_size else ()) + \
        (_PREFIX_KEYS if args.prefix_sharing else ()) + \
        (_SPEC_KEYS if args.speculate else ())
    for k in keys:
        v = summary[k]
        print(f"  {k:22s} {v:.4g}" if isinstance(v, float)
              else f"  {k:22s} {v}")
    # Diagnostics before the assertion-style invariant checks, so a CI
    # failure prints the readable ERROR line instead of a bare traceback.
    if engine.prefix is not None and \
            engine.prefix.pages_held > engine.prefix.retention_pages:
        print(f"ERROR: prefix index holds {engine.prefix.pages_held} pages "
              f"for finished lineages, beyond its retention of "
              f"{engine.prefix.retention_pages}", file=sys.stderr)
        return 1
    engine.pool.check_invariants()
    if engine.prefix is not None:
        engine.prefix.check_invariants(engine.pool)
    if summary["final_occupancy"] != 0:
        print("ERROR: slot pool leaked "
              f"{summary['final_occupancy']} slots", file=sys.stderr)
        return 1
    if args.page_size is not None:
        pool = engine.pool
        held = engine.prefix.pages_held if engine.prefix is not None else 0
        # Refcount-leak check, the paged analogue of the slot-leak check:
        # with every slot drained, the only legitimate references left
        # are the prefix index's holds — any page whose refcount is not
        # exactly its external-hold count leaked a reference (or was
        # freed with one outstanding).
        leaked = [p for p in range(1, pool.num_pages)
                  if pool.page_ref[p] != pool.external_holds[p]]
        if leaked:
            print(f"ERROR: page refcount leak on pages {leaked[:8]} "
                  f"({len(leaked)} total) after drain", file=sys.stderr)
            return 1
        if summary["final_live_pages"] != held:
            print("ERROR: page pool leaked "
                  f"{summary['final_live_pages'] - held} pages beyond the "
                  f"{held} prefix-index holds", file=sys.stderr)
            return 1
    if args.expect_defrag and summary["defrags"] == 0:
        print("ERROR: --expect-defrag but the run never defragged "
              "(page churn too low to exercise the paged pool)",
              file=sys.stderr)
        return 1
    if args.expect_moe_drop:
        if cfg.family != "moe":
            print(f"ERROR: --expect-moe-drop on a non-MoE arch "
                  f"({cfg.name} is family={cfg.family})", file=sys.stderr)
            return 1
        if summary["moe_assignments"] == 0:
            print("ERROR: --expect-moe-drop but the run recorded no MoE "
                  "routing assignments (aux accounting never reached the "
                  "engine metrics)", file=sys.stderr)
            return 1
        print(f"moe routing: {summary['moe_assignments']} assignments, "
              f"{summary['moe_dropped_assignments']} dropped "
              f"(rate {summary['moe_drop_rate']:.4f})")
    if args.expect_prefix_hits and summary["prefix_hits"] == 0:
        print("ERROR: --expect-prefix-hits but no admission mapped shared "
              "prefix pages (trace lacks a common prefix, or donors never "
              "finished before sharers arrived)", file=sys.stderr)
        return 1
    if args.speculate:
        # The speculative stream must serve exactly what plain decode
        # serves: tokens and finish reasons bit-for-bit; MI traces within
        # a float tolerance (a K-wide verify and a 1-wide decode pass
        # accumulate their gemms in different orders, and MI's entropy
        # cancellation amplifies those ulps to ~1e-7 — a real
        # verify/rollback bug moves MI by orders of magnitude more).
        import numpy as np
        with mesh:
            plain = build_engine(0)
            run_load(plain, make_trace())
        out = lambda e: {r.uid: (list(r.generated),  # noqa: E731
                                 [float(m) for m in r.mi_trace],
                                 r.finish_reason) for r in e.finished}
        got, want = out(engine), out(plain)
        same = set(got) == set(want) and all(
            (got[u][0], got[u][2]) == (want[u][0], want[u][2])
            and len(got[u][1]) == len(want[u][1])
            and np.allclose(got[u][1], want[u][1], rtol=0.0, atol=2e-5)
            for u in want)
        if not same:
            print("ERROR: speculative decode diverged from plain decode "
                  "(tokens differ, or MI traces beyond 2e-5)",
                  file=sys.stderr)
            return 1
        if args.expect_accept_rate is not None and \
                summary["draft_acceptance_rate"] < args.expect_accept_rate:
            print("ERROR: draft acceptance rate "
                  f"{summary['draft_acceptance_rate']:.3f} below the "
                  f"--expect-accept-rate {args.expect_accept_rate} floor",
                  file=sys.stderr)
            return 1
    print(f"served {summary['completed']} requests "
          f"({summary['tokens_generated']} tokens) — one PFP pass per decode "
          "step; escalations spent SVI samples only on gray-zone tokens.")
    return 0


def _tuning_epilogue(args, queries):
    """Post-run autoscheduler bookkeeping: prove the preloaded DB kept
    the hot path search-free (--expect-warm-cache) and/or persist what
    this run consulted (--save-schedule-db)."""
    from repro.tuning import cache as sched_cache

    counters = sched_cache.consult_counters()
    if args.schedule_db or args.expect_warm_cache or args.save_schedule_db:
        print(f"tuning-cache consults: {counters['consults']} "
              f"({counters['hits']} hits, {counters['misses']} misses)")
    if args.expect_warm_cache and counters["misses"] > 0:
        print("ERROR: --expect-warm-cache but the run missed the tuning "
              f"cache {counters['misses']} times (the schedule DB does not "
              "cover this model's shape set)", file=sys.stderr)
        return 1
    if args.save_schedule_db:
        from repro.tuning import measure as sched_measure

        cache = sched_cache.global_cache()
        tuned = 0
        for op, shape_key, dtype, backend in dict.fromkeys(queries or ()):
            if cache.get(op, shape_key, dtype, backend) is None:
                sched_measure.tune_into_cache(cache, op, shape_key, dtype,
                                              backend, mode="rank")
                tuned += 1
        path = cache.save(args.save_schedule_db)
        print(f"schedule-db: tuned {tuned} new entries, saved "
              f"{len(cache)} total -> {path}")
    return 0


def main():
    import contextlib

    args = _parse_args()
    from repro.core import dispatch
    from repro.tuning import cache as sched_cache

    if args.fuse_ops:
        dispatch.set_fusion(True)
    if args.schedule_db:
        n = len(sched_cache.load_global_cache(args.schedule_db))
        print(f"schedule-db: preloaded {n} tuned entries "
              f"from {args.schedule_db}")
    # Scope the warm-start proof to this run's consults, not import-time
    # warmup some earlier code path may have done.
    sched_cache.consult_counters(reset=True)
    with contextlib.ExitStack() as stack:
        queries = (stack.enter_context(sched_cache.record_shapes())
                   if args.save_schedule_db else None)
        rc = _serve(args)
    if rc == 0:
        rc = _tuning_epilogue(args, queries)
    return rc


if __name__ == "__main__":
    sys.exit(main())
