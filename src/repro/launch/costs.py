"""Trip-count-correct cost extraction for the roofline analysis.

Why not compiled.cost_analysis()? XLA reports the cost of a while-loop
*body* once, not multiplied by its trip count — a 100-layer scanned model
shows up ~100x too cheap. Two extractors fix this:

  * jaxpr_costs(fn, args): walks the closed jaxpr, counting exact
    dot_general/conv FLOPs and (unfused, upper-bound) operand/result bytes,
    multiplying through scan lengths. This is the GLOBAL program; divide by
    chip count for per-device terms (sharding is balanced by construction).

  * collective_bytes_scaled(hlo): parses the SPMD-partitioned optimized
    HLO, builds the computation call graph, extracts while-loop trip counts
    from their condition computations (iter < constant), and sums
    collective output bytes x loop multiplier. This is PER-DEVICE.
"""
from __future__ import annotations

import re
from typing import Any, Dict

import jax
import numpy as np

# ---------------------------------------------------------------------------
# jaxpr walker
# ---------------------------------------------------------------------------
_CALL_PARAMS = ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr",
                "branches", "fun_jaxpr")


def _aval_size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:  # noqa: BLE001
        return 1


def _aval_bytes(aval) -> int:
    try:
        return _aval_size(aval) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001
        return 0


def _dot_flops(eqn) -> int:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = 1
    for d in lc:
        k *= lhs.shape[d]
    return 2 * _aval_size(out) * k


def _conv_flops(eqn) -> int:
    lhs = eqn.invars[0].aval
    rhs = eqn.invars[1].aval  # kernel
    out = eqn.outvars[0].aval
    groups = eqn.params.get("feature_group_count", 1)
    k_spatial = 1
    # kernel shape excluding its IO feature dims per dnums; approximate with
    # total kernel size / out_features.
    dn = eqn.params["dimension_numbers"]
    out_feat = rhs.shape[dn.rhs_spec[0]]
    k = int(np.prod(rhs.shape)) // max(out_feat, 1)
    return 2 * _aval_size(out) * k // max(groups, 1)


def _jaxpr_cost(jaxpr) -> tuple:
    flops = 0
    bytes_ = 0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            flops += _dot_flops(eqn)
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.invars)
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.outvars)
            continue
        if prim == "conv_general_dilated":
            flops += _conv_flops(eqn)
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.invars)
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.outvars)
            continue
        if prim == "scan":
            f, b = _jaxpr_cost(eqn.params["jaxpr"].jaxpr)
            n = eqn.params["length"]
            flops += f * n
            bytes_ += b * n
            continue
        if prim == "while":
            f1, b1 = _jaxpr_cost(eqn.params["body_jaxpr"].jaxpr)
            f2, b2 = _jaxpr_cost(eqn.params["cond_jaxpr"].jaxpr)
            flops += f1 + f2  # unknown trip count: count once (rare here)
            bytes_ += b1 + b2
            continue
        if prim == "cond":
            branch_costs = [_jaxpr_cost(b.jaxpr)
                            for b in eqn.params["branches"]]
            f = max(c[0] for c in branch_costs)
            b = max(c[1] for c in branch_costs)
            flops += f
            bytes_ += b
            continue
        handled = False
        for p in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            sub = eqn.params.get(p)
            if sub is not None:
                inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                f, b = _jaxpr_cost(inner)
                flops += f
                bytes_ += b
                handled = True
                break
        if handled:
            continue
        # elementwise / reduction / data movement: 1 flop per output element
        out_b = sum(_aval_bytes(v.aval) for v in eqn.outvars)
        flops += sum(_aval_size(v.aval) for v in eqn.outvars)
        bytes_ += out_b + sum(_aval_bytes(v.aval) for v in eqn.invars)
    return flops, bytes_


def jaxpr_costs(fn, *args) -> Dict[str, float]:
    """Exact dot FLOPs + unfused byte upper bound for the GLOBAL program."""
    closed = jax.make_jaxpr(fn)(*args)
    flops, bytes_ = _jaxpr_cost(closed.jaxpr)
    return {"flops_global": float(flops), "bytes_global": float(bytes_)}


# ---------------------------------------------------------------------------
# HLO collective parser with while trip-count scaling
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# Computation headers: "%name (args...) -> type {" — args may contain
# nested parentheses (tuple types), so match only up to the first '('.
_COMP_HDR = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_CALLEE = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")


def _shape_bytes(s: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_computations(hlo: str):
    comps: Dict[str, dict] = {}
    cur = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        # Header lines end with '{' and are not instructions (no '=' before
        # the '(' of the arg list at top level, i.e. they start a comp).
        if stripped.endswith("{") and "=" not in stripped.split("(")[0]:
            m = _COMP_HDR.match(stripped)
            if m:
                cur = m.group(2)
                comps[cur] = {"coll": {}, "callees": [], "whiles": [],
                              "consts": []}
                if m.group(1):
                    entry = cur
                continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        s = stripped
        info = comps[cur]
        for m in re.finditer(r"constant\((\d+)\)", s):
            info["consts"].append(int(m.group(1)))
        if "=" in s:
            rhs = s.split("=", 1)[1]
            # collectives (skip -done halves of async pairs)
            for kind in _COLLECTIVES:
                if re.search(rf"\b{kind}(-start)?\(", rhs) and \
                        f"{kind}-done" not in rhs:
                    lhs_types = rhs.split(kind)[0]
                    out_b = _shape_bytes(lhs_types)
                    g = re.search(r"replica_groups=\[(\d+),(\d+)\]", rhs)
                    gsize = int(g.group(2)) if g else 1
                    info["coll"].setdefault(kind, []).append((out_b, gsize))
                    break
            if re.search(r"\bwhile\(", rhs):
                body = re.search(r"body=%?([\w\.\-]+)", rhs)
                cond = re.search(r"condition=%?([\w\.\-]+)", rhs)
                if body and cond:
                    info["whiles"].append((body.group(1), cond.group(1)))
            for m in _CALLEE.finditer(rhs):
                info["callees"].append(m.group(1))
    return comps, entry


def collective_bytes_scaled(hlo: str) -> Dict[str, Dict[str, float]]:
    """Per-device collective bytes, while-loops scaled by trip count.

    Returns {kind: {'operand': B, 'link': B}} where
      operand — sum of operand sizes (the assignment's §Roofline metric):
                all-gather operand = output/group, reduce-scatter operand =
                output*group, others = output size;
      link    — ring-algorithm per-device link traffic:
                AG/RS: (g-1)/g * full;  AR: 2 (g-1)/g * full;  others: out.
    """
    comps, entry = _parse_computations(hlo)
    empty = {k: {"operand": 0.0, "link": 0.0} for k in _COLLECTIVES}
    if entry is None:
        return empty

    def trip_count(cond_name: str) -> int:
        cond = comps.get(cond_name)
        if not cond or not cond["consts"]:
            return 1
        return max(cond["consts"])

    totals = {k: {"operand": 0.0, "link": 0.0} for k in _COLLECTIVES}

    def add(kind, out_b, g, mult):
        g = max(g, 1)
        if kind == "all-gather":
            operand, full = out_b / g, out_b
            link = (g - 1) / g * full
        elif kind == "reduce-scatter":
            operand, full = out_b * g, out_b * g
            link = (g - 1) / g * full
        elif kind == "all-reduce":
            operand, full = out_b, out_b
            link = 2 * (g - 1) / g * full
        else:  # all-to-all / collective-permute
            operand, link = out_b, out_b
        totals[kind]["operand"] += operand * mult
        totals[kind]["link"] += link * mult

    def visit(name: str, mult: float):
        if name not in comps:
            return
        info = comps[name]
        for kind, entries in info["coll"].items():
            for out_b, g in entries:
                add(kind, out_b, g, mult)
        handled = set()
        for body, cond in info["whiles"]:
            visit(body, mult * trip_count(cond))
            handled.add(body)
            handled.add(cond)
        for callee in info["callees"]:
            if callee not in handled:
                visit(callee, mult)

    visit(entry, 1.0)
    return totals
