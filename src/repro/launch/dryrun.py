import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first init, and only the dry-run wants 512
placeholder devices (tests and benches see the real single CPU device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Each cell writes results/dryrun/<arch>__<shape>__<mesh>[__<mode>].json with
memory_analysis, cost_analysis FLOPs/bytes and the collective-bytes
breakdown parsed from the optimized HLO (§Roofline reads these).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ASSIGNED_ARCHS  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402
from repro.launch import roofline  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.programs import build_program, cell_is_applicable  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "..", "..", "..", "results", "dryrun")


def _serving_probe(arch: str, formulation: str) -> dict:
    """Tiny deterministic engine run on the reduced config: records the
    abstention/escalation counts the uncertainty router produces, so
    decode cells carry a serving section comparable across PRs. The
    dry-run otherwise only compiles; this is the one executed probe
    (seconds on the reduced config, fixed seeds, XLA stack)."""
    from repro.bayes.convert import svi_to_pfp
    from repro.configs import reduced_config
    from repro.models import lm
    from repro.nn import pjit_hints
    from repro.serving.engine import (Engine, EngineConfig, RouterConfig,
                                      UncertaintyRouter, poisson_trace,
                                      run_load)

    import dataclasses

    # Widen the init posteriors (sigma 5e-2 vs the paper's 1e-4 init) so
    # the probe's MI signal actually exercises the router's three bands.
    cfg = dataclasses.replace(reduced_config(arch), sigma_init=5e-2)
    if not cfg.embed_inputs:
        return {"status": "skipped",
                "reason": "frame-embedding frontend (no token prompts)"}
    try:
        pjit_hints.set_rules(None)  # drop the 512-chip cell shardings
        router_cfg = RouterConfig(mi_continue=0.02, mi_abstain=0.5,
                                  escalate_samples=4)
        params = svi_to_pfp(lm.init_params(cfg, jax.random.PRNGKey(0)))
        engine = Engine(
            cfg, params,
            EngineConfig(slots=2, max_len=16, num_uncertainty_samples=16,
                         formulation=formulation, seed=0),
            router=UncertaintyRouter(cfg, router_cfg,
                                     formulation=formulation))
        trace = poisson_trace(6, rate=0.7, vocab_size=cfg.vocab_size,
                              seed=0, prompt_len=(3, 6),
                              max_new_tokens=(2, 4))
        s = run_load(engine, trace, max_steps=500)
        return {"status": "ok",
                "router": {"mi_continue": router_cfg.mi_continue,
                           "mi_abstain": router_cfg.mi_abstain,
                           "escalate_samples": router_cfg.escalate_samples},
                "requests": s["submitted"],
                "completed": s["completed"],
                "abstained": s["abstained"],
                "escalations": s["escalations"],
                "tokens_generated": s["tokens_generated"],
                "abstain_rate": round(s["abstain_rate"], 4),
                "escalation_rate": round(s["escalation_rate"], 4),
                "final_occupancy": s["final_occupancy"]}
    except Exception as e:  # noqa: BLE001
        return {"status": "error", "error": f"{type(e).__name__}: {e}"}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             mode_override=None, save: bool = True, tag: str = "",
             formulation: str = "srm", serve_params: str = "auto",
             impl: str = None) -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    ok, why = cell_is_applicable(arch, shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "mode_override": mode_override, "tag": tag}
    if not ok:
        result.update(status="skipped", reason=why)
        print(f"[SKIP] {arch} x {shape_name} x {mesh_name}: {why}")
        return _save(result) if save else result

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        prog = build_program(arch, shape_name, mesh,
                             mode_override=mode_override,
                             formulation=formulation,
                             impl=impl,
                             serve_params=serve_params)
        from repro.tuning import cache as schedule_cache  # noqa: E402

        with mesh:
            jitted = jax.jit(prog.fn, in_shardings=prog.in_shardings,
                             donate_argnums=prog.donate_argnums)
            # Record the dispatch layer's schedule-cache queries made while
            # tracing, so the result JSON names the schedule each kernel-impl
            # op would run (tuned describe() or 'default' on a cache miss).
            with schedule_cache.record_shapes() as sched_queries:
                lowered = jitted.lower(*prog.arg_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        schedules = {}
        for op, shape_key, dtype, backend in sched_queries:
            hit = schedule_cache.global_cache().get(op, shape_key, dtype,
                                                    backend)
            key = f"{op}|{'x'.join(map(str, shape_key))}|{dtype}"
            schedules[key] = hit.describe() if hit is not None else "default"

        mem = compiled.memory_analysis()
        mem_info = {}
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "alias_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    mem_info[attr] = int(v)
        print(compiled.memory_analysis())

        # XLA's cost_analysis counts while-loop bodies ONCE (a scanned
        # 36-layer model shows ~36x too cheap) — kept for reference only.
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jax: one dict per device
            cost = cost[0] if cost else {}
        xla_flops = float(cost.get("flops", 0.0))

        # Trip-count-correct costs: exact dot FLOPs from the jaxpr (global /
        # chips) + collective bytes from the partitioned HLO scaled by while
        # trip counts (per-device already).
        from repro.launch import costs as costlib

        jc = costlib.jaxpr_costs(prog.fn, *prog.arg_specs)
        flops = jc["flops_global"] / chips
        bytes_ = jc["bytes_global"] / chips

        hlo = compiled.as_text()
        coll = costlib.collective_bytes_scaled(hlo)
        coll_operand = float(sum(v["operand"] for v in coll.values()))
        coll_link = float(sum(v["link"] for v in coll.values()))
        # Bottleneck classification uses physical ring-link traffic; the
        # operand-sum (assignment metric) is reported alongside.
        coll_total = coll_link

        terms = roofline.roofline_terms(flops, bytes_, coll_total)
        shape_cfg = SHAPES[shape_name]
        mf = roofline.model_flops(prog.meta, shape_cfg.kind,
                                  shape_cfg.seq_len, shape_cfg.global_batch)
        mf_per_dev = mf / chips
        result.update(
            status="ok",
            chips=chips,
            program=prog.name,
            impl=prog.meta.get("impl"),
            schedules=schedules,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops_per_device=flops,
            bytes_per_device=bytes_,
            xla_cost_analysis_flops=xla_flops,
            collective_bytes=coll,
            collective_bytes_operand=coll_operand,
            collective_bytes_link=coll_link,
            collective_bytes_total=coll_total,
            memory_analysis=mem_info,
            model_flops_total=mf,
            model_flops_per_device=mf_per_dev,
            useful_flops_ratio=(mf_per_dev / flops) if flops else None,
            hlo_bytes=len(hlo),
            **terms,
        )
        per_dev_hbm = mem_info.get("argument_size_in_bytes", 0) + \
            mem_info.get("temp_size_in_bytes", 0) + \
            mem_info.get("output_size_in_bytes", 0)
        # XLA:CPU has no native bf16: it materializes f32 copies of bf16
        # tensors and breaks aliasing for them, roughly doubling temp for
        # bf16-dominated programs. tpu_hbm_estimate halves temp as the
        # corrected (still conservative) TPU figure; EXPERIMENTS.md §Dry-run
        # documents this.
        alias = mem_info.get("alias_size_in_bytes", 0)
        tpu_est = mem_info.get("argument_size_in_bytes", 0) + \
            mem_info.get("temp_size_in_bytes", 0) / 2 + \
            max(mem_info.get("output_size_in_bytes", 0) - alias, 0)
        fits = tpu_est < 16e9
        result["hbm_bytes_per_device"] = per_dev_hbm
        result["tpu_hbm_estimate"] = tpu_est
        result["fits_16gb_hbm"] = bool(fits)
        print(f"[OK]  {prog.name} mesh={mesh_name} chips={chips} "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"flops/dev={flops:.3e} bytes/dev={bytes_:.3e} "
              f"coll/dev={coll_total:.3e} bottleneck={terms['bottleneck']} "
              f"hbm/dev={per_dev_hbm/1e9:.2f}GB fits={fits}")
    except Exception as e:  # noqa: BLE001
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        print(f"[ERR] {arch} x {shape_name} x {mesh_name}: {e}")
    if SHAPES[shape_name].kind == "decode":
        result["serving"] = _serving_probe(arch, formulation)
        if result["serving"].get("status") == "ok":
            sv = result["serving"]
            print(f"      serving probe: {sv['completed']} completed, "
                  f"{sv['abstained']} abstained, "
                  f"{sv['escalations']} escalations")
    return _save(result) if save else result


def _save(result: dict) -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    mode = result.get("mode_override") or ""
    tag = result.get("tag") or ""
    suffix = (f"__{mode}" if mode else "") + (f"__{tag}" if tag else "")
    path = os.path.join(
        RESULTS_DIR,
        f"{result['arch']}__{result['shape']}__{result['mesh']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=2, default=str)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--mode", default=None,
                    help="override program mode (svi/pfp/deterministic)")
    ap.add_argument("--tag", default="", help="result-file suffix")
    ap.add_argument("--formulation", default="srm", choices=["srm", "var"])
    ap.add_argument("--impl", default=None, choices=["xla", "kernel"],
                    help="PFP operator implementation (core/dispatch.py)")
    ap.add_argument("--serve-params", default="auto",
                    choices=["auto", "tp", "fsdp"])
    ap.add_argument("--schedule-cache", default=None,
                    help="tuned-schedule cache JSON to load (repro.tuning); "
                         "kernel-impl cells then compile with and report the "
                         "tuned block shapes")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    if args.schedule_cache:
        from repro.tuning import load_global_cache

        load_global_cache(args.schedule_cache)

    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    statuses = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, multi_pod=mp,
                             mode_override=args.mode, tag=args.tag,
                             formulation=args.formulation,
                             impl=args.impl,
                             serve_params=args.serve_params)
                statuses.append((arch, shape, r["mesh"], r["status"]))
    bad = [s for s in statuses if s[3] == "error"]
    print(f"\n== {len(statuses)} cells: "
          f"{sum(1 for s in statuses if s[3]=='ok')} ok, "
          f"{sum(1 for s in statuses if s[3]=='skipped')} skipped, "
          f"{len(bad)} errors ==")
    for b in bad:
        print("  ERROR:", b)
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
