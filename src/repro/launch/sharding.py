"""Sharding rules: FSDP x TP x EP x SP PartitionSpecs for every program.

Rules are role-based (matched on parameter-tree paths) with divisibility
guards: an axis is only sharded when its size divides the mesh-axis size —
otherwise that dimension stays replicated and GSPMD inserts the collectives
it needs. This keeps every (arch x shape x mesh) cell *lowerable*; the perf
pass then tightens the interesting cells.

Parameters are sharded over ('data' [FSDP], 'model' [TP/EP]) but never over
'pod' (cross-pod links are slow DCN; parameters are replicated across pods
and gradients reduced hierarchically). Batch dims shard over pod+data.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, dp_axes, fsdp_axis


def _shard_if(dim: int, mesh, axis: Optional[str]):
    if axis is None:
        return None
    if isinstance(axis, tuple):
        total = 1
        for a in axis:
            total *= axis_size(mesh, a)
        return axis if dim % total == 0 else None
    return axis if dim % axis_size(mesh, axis) == 0 else None


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


# (regex on path, spec-builder(shape, mesh) -> PartitionSpec (without any
# leading scan axis)). First match wins. `d`=fsdp axis, `m`='model'.
def _param_rules():
    return [
        # embedding: vocab dim only — GSPMD's masked-gather + all-reduce is
        # the one well-supported partitioned-gather pattern; feature-sharded
        # tables trip an hlo-verifier bug inside grad-accum scans.
        (r"embed/table", lambda s, M, d, m: P(_shard_if(s[0], M, m), None)),
        (r"lm_head/w", lambda s, M, d, m: P(_shard_if(s[0], M, d),
                                            _shard_if(s[1], M, m))),
        # attention projections: TP on the head axis side
        (r"attn/w[qkv]/w", lambda s, M, d, m: P(_shard_if(s[0], M, d),
                                                _shard_if(s[1], M, m))),
        (r"attn/wo/w", lambda s, M, d, m: P(_shard_if(s[0], M, m),
                                            _shard_if(s[1], M, d))),
        # dense MLP: TP on d_ff
        (r"(mlp|shared)/w_(up|gate)/w", lambda s, M, d, m: P(
            _shard_if(s[0], M, d), _shard_if(s[1], M, m))),
        (r"(mlp|shared)/w_down/w", lambda s, M, d, m: P(
            _shard_if(s[0], M, m), _shard_if(s[1], M, d))),
        # MoE experts: EP on the expert axis, FSDP inside
        (r"experts/w_(up|gate)", lambda s, M, d, m: P(
            _shard_if(s[0], M, m), _shard_if(s[1], M, d), None)),
        (r"experts/w_down", lambda s, M, d, m: P(
            _shard_if(s[0], M, m), None, _shard_if(s[2], M, d))),
        (r"moe/router/w", lambda s, M, d, m: P(None, None)),
        # RG-LRU block
        (r"rec/w_[xy]/w", lambda s, M, d, m: P(_shard_if(s[0], M, d),
                                               _shard_if(s[1], M, m))),
        (r"rec/w_out/w", lambda s, M, d, m: P(_shard_if(s[0], M, m),
                                              _shard_if(s[1], M, d))),
        (r"rec/w_[ri]/w", lambda s, M, d, m: P(None, _shard_if(s[1], M, m))),
        (r"rec/conv_w", lambda s, M, d, m: P(None, _shard_if(s[1], M, m))),
        (r"rec/lam", lambda s, M, d, m: P(_shard_if(s[0], M, m))),
        # Mamba2
        (r"ssm/in_proj/w", lambda s, M, d, m: P(_shard_if(s[0], M, d), None)),
        (r"ssm/out_proj/w", lambda s, M, d, m: P(None, _shard_if(s[1], M, d))),
        (r"ssm/conv_w", lambda s, M, d, m: P(None, None)),
    ]


def param_pspec(path_str: str, shape, mesh, *, serve: bool = False) -> P:
    """serve=True drops the FSDP ('data') factor: inference weights are
    small (bf16, no optimizer state) and in-dim sharding would turn every
    matmul into a partial-sum all-reduce of pod-scale activations. TP-only
    weights keep collectives to the TP boundary."""
    d, m = (None if serve else fsdp_axis(mesh)), "model"
    # Strip the Bayesian leaf suffix (mu/rho/srm/var share the weight spec)
    # and bias leaves are small -> replicated.
    core = re.sub(r"/(mu|rho|srm|var)$", "", path_str)
    if core.endswith("/b"):
        return P()
    scanned = core.startswith("stack/")
    rank_offset = 1 if scanned else 0
    eff_shape = shape[rank_offset:]
    for pat, rule in _param_rules():
        if re.search(pat, core):
            spec = rule(eff_shape, mesh, d, m)
            if scanned:
                spec = P(None, *spec)
            return spec
    return P()  # norms, scalars, biases -> replicated


def params_shardings(param_shapes, mesh, *, serve: bool = False):
    """Pytree of NamedSharding matching a pytree of ShapeDtypeStructs."""

    def mk(path, leaf):
        spec = param_pspec(_path_str(path), leaf.shape, mesh, serve=serve)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(mk, param_shapes)


# -- batch / state shardings --------------------------------------------------
def batch_pspec(name: str, shape, mesh) -> P:
    dp = dp_axes(mesh)
    b = shape[0] if shape else 1
    bspec = _shard_if(b, mesh, dp)
    if bspec is None and len(dp) > 1:
        bspec = _shard_if(b, mesh, (dp[-1],))
    rest = [None] * (len(shape) - 1)
    if name in ("frame_embeddings", "image_embeddings") and len(shape) == 3:
        rest[-1] = _shard_if(shape[-1], mesh, "model")
    return P(bspec, *rest)


def batch_shardings(batch_shapes, mesh):
    def mk(path, leaf):
        name = _path_str(path)
        return NamedSharding(mesh, batch_pspec(name.split("/")[-1],
                                               leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(mk, batch_shapes)


def state_pspec(path_str: str, shape, mesh) -> P:
    """Decode-state shardings (KV caches, recurrent/SSM states).

    KVCache leaves: (B, Hkv, S, D) [+ leading group axis when scanned].
    Sequence dim shards over 'model' (SP — flash-decoding style) whenever
    the head dim can't fill the TP axis; batch over pod+data.
    """
    dp = dp_axes(mesh)
    scanned = path_str.startswith("stack/")
    off = 1 if scanned else 0
    eff = shape[off:]
    spec: list = [None] * len(eff)
    if len(eff) == 4 and ("k_mu" in path_str or "v_mu" in path_str
                          or "v_var" in path_str):
        b, h, s, d = eff
        spec[0] = _shard_if(b, mesh, dp) or _shard_if(b, mesh, (dp[-1],))
        if _shard_if(h, mesh, "model"):
            spec[1] = "model"
        else:
            spec[2] = _shard_if(s, mesh, "model")
    elif len(eff) == 4:  # SSM state (B, H, P, N)
        b, h, p_, n = eff
        spec[0] = _shard_if(b, mesh, dp) or _shard_if(b, mesh, (dp[-1],))
        spec[1] = _shard_if(h, mesh, "model")
    elif len(eff) >= 1:
        spec[0] = _shard_if(eff[0], mesh, dp) or _shard_if(eff[0], mesh,
                                                           (dp[-1],))
    if scanned:
        spec = [None] + spec
    return P(*spec)


def state_shardings(state_shapes, mesh):
    def mk(path, leaf):
        return NamedSharding(mesh, state_pspec(_path_str(path), leaf.shape,
                                               mesh))

    return jax.tree_util.tree_map_with_path(mk, state_shapes)


def replicated(mesh):
    return NamedSharding(mesh, P())
