"""Program builders for the dry-run and the launchers.

Maps every (arch x input-shape) cell to a concrete jittable program plus
abstract argument specs (ShapeDtypeStructs — never allocated) and
shardings:

  train_4k     -> SVI ELBO train step (the paper's training mode, 1 MC
                  sample, remat'd scan, Adam) — fp32 variational params
  prefill_32k  -> PFP prefill (single analytic pass, fills decode state)
                  — bf16 converted (mu, srm) deployment params
  decode_32k / long_500k -> PFP serve step (1 new token against a
                  seq_len-sized state) — bf16 deployment params
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.bayes.convert import svi_to_pfp
from repro.bayes.variational import KLSchedule
from repro.configs import get_config
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.core.modes import Mode
from repro.launch import sharding as shlib
from repro.models import lm
from repro.nn.module import Context
from repro.serving.decode import make_prefill_step, make_serve_step
from repro.training.optimizer import Adam
from repro.training.train_loop import (TrainState, init_train_state,
                                       make_svi_train_step)


class Program(NamedTuple):
    name: str
    fn: Any                 # jittable callable
    arg_specs: tuple        # pytree of ShapeDtypeStruct per positional arg
    in_shardings: tuple
    donate_argnums: tuple
    meta: dict


def _sds(tree, dtype=None):
    def mk(x):
        dt = dtype if (dtype is not None and
                       jnp.issubdtype(x.dtype, jnp.floating)) else x.dtype
        return jax.ShapeDtypeStruct(x.shape, dt)

    return jax.tree_util.tree_map(mk, tree)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                compute_dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b = shape.global_batch
    t = 1 if shape.kind == "decode" else shape.seq_len
    specs: dict = {}
    if cfg.embed_inputs:
        specs["tokens"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
    else:
        specs["frame_embeddings"] = jax.ShapeDtypeStruct(
            (b, t, cfg.d_model), compute_dtype)
    if cfg.family == "vlm":
        specs["image_embeddings"] = jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, cfg.d_model), compute_dtype)
    if shape.kind == "train":
        specs["targets"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
    if shape.kind == "decode":
        specs["positions"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        specs["cache_len"] = jax.ShapeDtypeStruct((b,), jnp.int32)
    return specs


def variational_param_specs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))


def pfp_param_specs(cfg: ModelConfig, dtype=jnp.bfloat16):
    var_specs = variational_param_specs(cfg)
    return jax.eval_shape(
        functools.partial(svi_to_pfp, rep="srm", dtype=dtype), var_specs)


def decode_state_specs(cfg: ModelConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16):
    st = jax.eval_shape(
        functools.partial(lm.init_decode_state, cfg, batch, max_len))
    return _sds(st, dtype)


def build_program(arch: str, shape_name: str, mesh, *,
                  mode_override: Optional[str] = None,
                  query_chunk: Optional[int] = None,
                  formulation: str = "srm",
                  impl: Optional[str] = None,
                  serve_params: str = "tp",
                  logical_rules: Optional[dict] = None) -> Program:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count()}

    # Bind logical activation-sharding anchors for this (cfg, shape, mesh).
    from repro.launch.mesh import dp_axes
    from repro.nn import pjit_hints

    batch_axes = dp_axes(mesh)
    # Train: shard the residual stream's d_model over 'model' (the scan-
    # saved carries dominate memory). Serve: keep it unsharded — with
    # TP-only weights that leaves exactly Megatron's two partial-sum
    # reductions per layer instead of AG(x)+AR(out) on every projection.
    embed_axis = "model" if shape.kind == "train" else None
    seq_axis = None
    if cfg.family == "ssm":
        # Attention-free: the 'model' axis carries no TP for activations, so
        # fold it into the batch shards (else e.g. the (B,T,50280) mamba
        # logits only shard 16-way and blow the per-device HBM budget).
        # When the batch can't fill it (prefill_32k: batch 32), the
        # constrain() fallback drops 'model' from the batch dim and the seq
        # dim picks it up instead (sequence parallelism — the conv halo and
        # SSD chunk-state exchange become collective-permutes).
        batch_axes = batch_axes + ("model",)
        embed_axis = None  # 'model' is consumed by batch or seq
        seq_axis = "model"
    rules = {
        "mesh": mesh,
        # The d_model axis of layer-boundary activations shards over 'model'
        # so the scan-saved residual stream (the dominant train-time temp:
        # L x (B,T,D) fp32 for backward) splits 16-ways beyond the batch.
        "batch": batch_axes,
        "state_batch": dp_axes(mesh),  # KV-cache batch dim (constrain_kv)
        "seq": seq_axis,
        "embed": embed_axis,
        "vocab": "model",
        "expert": "model",     # EP: experts across the TP axis
        "capacity": "data",    # expert-buffer slots across the DP axis
        "ffn": None,
    }
    if logical_rules:
        rules.update(logical_rules)
    pjit_hints.set_rules(rules)

    meta["formulation"] = formulation
    # Which registered PFP operator implementation the serve programs run
    # (core/dispatch.py); recorded so the dry-run result JSON names the
    # operator stack that was benchmarked.
    from repro.core.dispatch import resolve_impl

    meta["impl"] = resolve_impl(impl)
    if serve_params == "auto" or serve_params == "tp":
        # TP-only weights kill the per-layer AG/AR storm (§Perf cell A) but
        # replicate params over 'data': only safe when the bf16 (mu, srm)
        # deployment pytree fits comfortably alongside the KV/state cache.
        if cfg.param_count() * 2 * 2 / 16 > 4e9:  # >4 GB/dev at TP-16
            serve_params = "fsdp"
        else:
            serve_params = "tp"
    meta["serve_params"] = serve_params
    serve_tp = serve_params == "tp"
    if shape.kind == "train":
        return _train_program(cfg, shape, mesh, meta, mode_override)
    if shape.kind == "prefill":
        return _prefill_program(cfg, shape, mesh, meta, mode_override,
                                formulation, serve_tp, meta["impl"])
    return _decode_program(cfg, shape, mesh, meta, mode_override, formulation,
                           serve_tp, meta["impl"])


def _train_program(cfg, shape, mesh, meta, mode_override) -> Program:
    optimizer = Adam(learning_rate=1e-3, clip_norm=1.0)
    mode = Mode.parse(mode_override) if mode_override else Mode.SVI

    # Grad-accumulation microbatching: big models trade steps for activation
    # memory (the per-microbatch live set shrinks linearly). NOTE §Perf:
    # scaling this by active params was tried and REFUTED — MoE train
    # collectives are dispatch-dominated, and fewer microbatches only
    # inflated activation memory (llama4: 21 -> 48 GB) for ~0% collective
    # gain, so the heuristic stays on total params (activation safety).
    n_params = meta["params"]
    if n_params > 3e10:
        num_micro = 8
    elif n_params > 5e9:
        num_micro = 4
    else:
        num_micro = 1
    meta["num_microbatches"] = num_micro

    def forward_fn(params, batch, ctx):
        import dataclasses as _dc

        from repro.core.gaussian import is_gaussian

        # Mixed precision: bf16 activations/weight-casts, fp32 master
        # weights + loss (logits upcast inside elbo_loss).
        ctx = _dc.replace(ctx, compute_dtype=jnp.bfloat16)
        logits, aux, _ = lm.forward(params, cfg, batch, ctx, remat=True)
        if is_gaussian(logits):
            logits = logits.mean
        return logits.astype(jnp.float32), aux

    num_data = shape.global_batch * shape.seq_len * 1000  # nominal corpus
    step_fn = make_svi_train_step(
        forward_fn, optimizer, num_data=num_data,
        kl_schedule=KLSchedule(alpha_max=0.25, anneal_steps=1000),
        num_microbatches=num_micro)

    if mode != Mode.SVI:
        def forward_det(params, batch, ctx):
            return forward_fn(params, batch,
                              Context(mode=mode, key=ctx.key))
        step_fn = make_svi_train_step(
            forward_det, optimizer, num_data=num_data,
            num_microbatches=num_micro)

    param_specs = variational_param_specs(cfg)
    opt_specs = jax.eval_shape(optimizer.init, param_specs)
    state_specs = TrainState(
        params=param_specs, opt_state=opt_specs,
        step=jax.ShapeDtypeStruct((), jnp.int32))
    batch_specs = input_specs(cfg, shape, compute_dtype=jnp.float32)
    key_spec = jax.eval_shape(lambda: jax.random.PRNGKey(0))

    p_sh = shlib.params_shardings(param_specs, mesh)
    opt_sh = type(opt_specs)(
        step=shlib.replicated(mesh),
        m=shlib.params_shardings(param_specs, mesh),
        v=shlib.params_shardings(param_specs, mesh))
    state_sh = TrainState(params=p_sh, opt_state=opt_sh,
                          step=shlib.replicated(mesh))
    in_sh = (state_sh, shlib.batch_shardings(batch_specs, mesh),
             shlib.replicated(mesh))

    return Program(
        name=f"{cfg.name}:{meta['shape']}:train[{mode.value}]",
        fn=step_fn,
        arg_specs=(state_specs, batch_specs, key_spec),
        in_shardings=in_sh,
        donate_argnums=(0,),
        meta=meta,
    )


def _prefill_program(cfg, shape, mesh, meta, mode_override,
                     formulation="srm", serve_tp=True,
                     impl=None) -> Program:
    mode = Mode.parse(mode_override) if mode_override else Mode.PFP
    fn = make_prefill_step(cfg, max_len=shape.seq_len, mode=mode,
                           formulation=formulation, impl=impl)
    param_specs = (pfp_param_specs(cfg) if mode == Mode.PFP
                   else _sds(variational_param_specs(cfg), jnp.bfloat16))
    batch_specs = input_specs(cfg, shape)
    in_sh = (shlib.params_shardings(param_specs, mesh, serve=serve_tp),
             shlib.batch_shardings(batch_specs, mesh))
    return Program(
        name=f"{cfg.name}:{meta['shape']}:prefill[{mode.value}]",
        fn=fn,
        arg_specs=(param_specs, batch_specs),
        in_shardings=in_sh,
        donate_argnums=(),
        meta=meta,
    )


def _decode_program(cfg, shape, mesh, meta, mode_override,
                    formulation="srm", serve_tp=True,
                    impl=None) -> Program:
    mode = Mode.parse(mode_override) if mode_override else Mode.PFP
    fn = make_serve_step(cfg, mode=mode, formulation=formulation, impl=impl)
    param_specs = (pfp_param_specs(cfg) if mode == Mode.PFP
                   else _sds(variational_param_specs(cfg), jnp.bfloat16))
    batch_specs = input_specs(cfg, shape)
    state_specs = decode_state_specs(cfg, shape.global_batch, shape.seq_len)
    in_sh = (shlib.params_shardings(param_specs, mesh, serve=serve_tp),
             shlib.batch_shardings(batch_specs, mesh),
             shlib.state_shardings(state_specs, mesh))
    return Program(
        name=f"{cfg.name}:{meta['shape']}:decode[{mode.value}]",
        fn=fn,
        arg_specs=(param_specs, batch_specs, state_specs),
        in_shardings=in_sh,
        donate_argnums=(2,),
        meta=meta,
    )


def cell_is_applicable(arch: str, shape_name: str) -> tuple[bool, str]:
    """long_500k only runs on sub-quadratic archs (DESIGN.md §6)."""
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 524k-token decode requires "
                       "sub-quadratic attention (skip noted in DESIGN.md)")
    return True, ""
