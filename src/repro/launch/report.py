"""Aggregate dry-run JSONs into the §Dry-run and §Roofline tables."""
from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "..", "..", "..", "results", "dryrun")


def load(mesh: str = "pod", tag: str = ""):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") != mesh or (r.get("tag") or "") != tag:
            continue
        if r.get("mode_override"):
            continue
        rows.append(r)
    return rows


def roofline_table(mesh: str = "pod", tag: str = "") -> str:
    rows = load(mesh, tag)
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | MODEL/HLO flops | HBM/dev (TPU est) | fits |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                f"(full attention) | — | — | — |")
            continue
        ratio = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['bottleneck']} | "
            f"{ratio:.2f} | "
            f"{r.get('tpu_hbm_estimate', 0) / 1e9:.1f} GB | "
            f"{'yes' if r.get('fits_16gb_hbm') else 'NO'} |")
    return "\n".join(lines)


def dryrun_table(mesh: str = "pod") -> str:
    rows = load(mesh)
    hdr = ("| arch | shape | program | lower s | compile s | flops/dev | "
           "bytes/dev | coll link B/dev | AG/AR/RS/A2A/CP (operand B) |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | skipped | — | — |"
                         f" — | — | — | — |")
            continue
        cb = r.get("collective_bytes", {})

        def op(kind):
            v = cb.get(kind, {})
            return f"{v.get('operand', 0):.2g}" if isinstance(v, dict) else "0"

        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['program'].split(':')[-1]} | "
            f"{r['lower_s']} | {r['compile_s']} | "
            f"{r['flops_per_device']:.3g} | {r['bytes_per_device']:.3g} | "
            f"{r['collective_bytes_link']:.3g} | "
            f"{op('all-gather')}/{op('all-reduce')}/{op('reduce-scatter')}/"
            f"{op('all-to-all')}/{op('collective-permute')} |")
    return "\n".join(lines)


def pick_hillclimb_cells(mesh: str = "pod"):
    """Worst roofline fraction, most collective-bound, most PFP-central."""
    rows = [r for r in load(mesh) if r["status"] == "ok"]
    worst = min(rows, key=lambda r: r.get("roofline_fraction", 1.0))
    coll = max(rows, key=lambda r: (r["collective_s"] /
                                    max(r["step_time_lower_bound_s"], 1e-12)))
    return worst, coll


if __name__ == "__main__":
    import sys

    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "pod"
    if which == "roofline":
        print(roofline_table(mesh))
    elif which == "dryrun":
        print(dryrun_table(mesh))
    else:
        w, c = pick_hillclimb_cells(mesh)
        print("worst roofline fraction:", w["arch"], w["shape"],
              f"{w.get('roofline_fraction'):.3f}")
        print("most collective-bound:", c["arch"], c["shape"],
              f"coll={c['collective_s']:.3g}s vs compute={c['compute_s']:.3g}s")
