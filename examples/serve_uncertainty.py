"""Batched PFP serving with uncertainty-aware abstention.

Demonstrates the serving substrate: a Batcher admits requests into decode
slots; every step is ONE probabilistic forward pass producing logit means
and variances for the whole batch; requests whose next-token mutual
information exceeds the threshold abstain (the BNN says "I don't know").

Run:  PYTHONPATH=src python examples/serve_uncertainty.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.bayes.convert import svi_to_pfp
from repro.configs import get_config
from repro.core.modes import Mode
from repro.models import lm
from repro.nn.module import Context
from repro.serving.batcher import Batcher, Request
from repro.serving.decode import uncertainty_decode

MAX_LEN = 64
BATCH = 4


def main():
    cfg = dataclasses.replace(
        get_config("granite-8b"), num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512)
    params = svi_to_pfp(lm.init_params(cfg, jax.random.PRNGKey(0)),
                        dtype=jnp.float32)
    ctx = Context(mode=Mode.PFP)

    batcher = Batcher(batch_size=BATCH, max_len=MAX_LEN)
    rng = np.random.default_rng(0)
    for uid in range(6):
        batcher.submit(Request(uid=uid,
                               prompt=rng.integers(0, 512, 8).astype(np.int32),
                               max_new_tokens=5))

    states = lm.init_decode_state(cfg, BATCH, MAX_LEN)
    positions = np.zeros(BATCH, np.int32)
    last_logits = None

    step_i = 0
    while not batcher.idle:
        admitted = batcher.fill_slots()
        for slot, req in admitted:
            # prefill the prompt token-by-token into this slot's cache rows
            # (a production server would run a batched prefill program).
            for t, tok in enumerate(req.prompt):
                inp = {"tokens": jnp.full((BATCH, 1), int(tok), jnp.int32),
                       "positions": jnp.full((BATCH, 1), t, jnp.int32),
                       "cache_len": jnp.full((BATCH,), t + 1, jnp.int32)}
                logits, states = lm.decode_step(params, cfg, inp, states, ctx)
            positions[slot] = len(req.prompt)
            last_logits = logits

        if last_logits is None:
            break
        out = uncertainty_decode(last_logits.mean, last_logits.var,
                                 jax.random.PRNGKey(step_i),
                                 mi_threshold=2.0)
        for slot, req in batcher.active():
            batcher.record(slot, int(out.token[slot]),
                           float(out.mutual_info[slot]),
                           bool(out.abstain[slot]))
        inp = {"tokens": out.token[:, None].astype(jnp.int32),
               "positions": jnp.asarray(positions)[:, None],
               "cache_len": jnp.asarray(positions + 1)}
        last_logits, states = lm.decode_step(params, cfg, inp, states, ctx)
        positions = positions + 1
        step_i += 1
        if step_i > 40:
            break

    print("request outcomes:")
    # finished requests were evicted from slots; report what we traced
    print(f"  served {6} requests in {step_i} decode steps "
          f"(batch={BATCH}, one PFP pass per step — an SVI server would "
          f"need 30x the forward passes for the same MI estimates)")


if __name__ == "__main__":
    main()
