"""Quickstart: the paper's full pipeline in ~2 minutes on CPU.

1. SVI-train a Bayesian MLP on synthetic Dirty-MNIST   (paper §4)
2. Convert to a PFP deployment artifact                (mu, E[w^2]; §5)
3. One analytic forward pass -> predictions + calibrated uncertainty
4. Show OOD detection: texture images get high epistemic uncertainty.
5. Flip the same model onto the Pallas kernel path     (core/dispatch.py)
6. Autotune per-op kernel schedules for this model     (repro.tuning, §6)
7. Serve an LM through the continuous-batching engine  (repro.serving.engine)
8. Paged Gaussian KV-cache: page-pool decode memory     (EngineConfig(page_size=N))
9. Prefix sharing: refcounted copy-on-write pages for a shared system prompt
10. Speculative decoding gated by the PFP's own uncertainty  (repro.serving)
11. Fleet serving: two disaggregated replicas behind a prefix router
12. Observability: deterministic traces (Perfetto-viewable), metrics
    registry exports, live per-op profile, uncertainty telemetry (repro.obs)
13. Warm-start fleet schedule DB: tune once, persist, every replica
    serves warm with zero schedule search on the hot path (repro.tuning)
14. Uncertainty-aware MoE decode: routed top-k experts through the
    grid-level batched-expert kernel, drop accounting on the aux-loss-free
    path (nn/moe.py, kernels/pfp_moe.py)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.bayes import metrics as bm
from repro.bayes.convert import svi_to_pfp
from repro.bayes.variational import KLSchedule
from repro.core.modes import Mode
from repro.data.dirty_mnist import batches, dirty_mnist
from repro.models.simple import mlp_forward, mlp_init
from repro.nn.module import Context
from repro.training.optimizer import Adam
from repro.training.train_loop import init_train_state, make_svi_train_step


def main():
    print("== 1. SVI training (ELBO + KL annealing, Adam) ==")
    (x_train, y_train), evals = dirty_mnist(n_train=1200, n_eval=300)
    params = mlp_init(jax.random.PRNGKey(0), d_hidden=64, sigma_init=1e-3)

    def fwd(p, batch, ctx):
        return mlp_forward(p, batch["x"], ctx), 0.0

    opt = Adam(learning_rate=3e-3)
    step = jax.jit(make_svi_train_step(
        fwd, opt, num_data=len(x_train),
        kl_schedule=KLSchedule(alpha_max=0.25, anneal_steps=150)))
    state = init_train_state(params, opt)
    for i, (bx, by) in enumerate(
            batches(x_train.reshape(-1, 784), y_train, 100, epochs=25)):
        state, m = step(state, {"x": jnp.asarray(bx),
                                "targets": jnp.asarray(by)},
                        jax.random.PRNGKey(i))
        if i % 100 == 0:
            print(f"  step {i:4d}  loss={float(m['loss']):.3f} "
                  f"nll={float(m['nll']):.3f} kl/n={float(m['kl']):.4f}")

    print("== 2. Convert SVI -> PFP (precompute E[w^2], calibrate) ==")
    pfp_params = svi_to_pfp(state.params, calibration_factor=1.0)

    print("== 3. Single probabilistic forward pass ==")
    ctx = Context(mode=Mode.PFP)
    for split in ("clean", "ambiguous", "ood"):
        imgs = evals[split][0]
        out = mlp_forward(pfp_params, jnp.asarray(imgs.reshape(-1, 784)), ctx)
        m = bm.pfp_predictive_metrics(jax.random.PRNGKey(1), out.mean,
                                      out.var, num_samples=50)
        labels = evals[split][1]
        acc = (np.asarray(m["pred"]) == labels).mean() if labels is not None \
            else float("nan")
        print(f"  {split:10s} acc={acc:.3f}  "
              f"total_unc={float(np.mean(m['total'])):.3f}  "
              f"aleatoric(SME)={float(np.mean(m['aleatoric'])):.3f}  "
              f"epistemic(MI)={float(np.mean(m['mi'])):.3f}")

    print("== 4. OOD detection (AUROC, paper Table 1) ==")

    def unc(split):
        imgs = evals[split][0]
        out = mlp_forward(pfp_params, jnp.asarray(imgs.reshape(-1, 784)), ctx)
        mm = bm.pfp_predictive_metrics(jax.random.PRNGKey(2), out.mean,
                                       out.var, 50)
        return np.asarray(mm["mi"])  # MI = the paper's OOD metric

    print(f"  AUROC(ood vs clean, MI) = "
          f"{bm.auroc(unc('ood'), unc('clean')):.3f}")

    print("== 5. Flipping the kernel path ==")
    # Every PFP op resolves through the impl-dispatch registry
    # (repro.core.dispatch): 'xla' runs the pure-jnp graph, 'kernel' the
    # Pallas TPU kernels (interpret mode off-TPU, so this works on CPU
    # too — slowly, as a correctness demonstration). Flip one forward via
    # the context...
    xs = jnp.asarray(evals["clean"][0][:32].reshape(-1, 784))
    out_k = mlp_forward(pfp_params, xs, Context(mode=Mode.PFP, impl="kernel"))
    out_x = mlp_forward(pfp_params, xs, Context(mode=Mode.PFP, impl="xla"))
    drift = float(jnp.max(jnp.abs(out_k.mean - out_x.mean)))
    print(f"  max |kernel - xla| logit mean drift: {drift:.2e}")
    # ...or flip the whole process when no explicit impl is set:
    from repro.core.dispatch import set_default_impl

    set_default_impl("kernel")
    try:
        out_default = mlp_forward(pfp_params, xs, Context(mode=Mode.PFP))
        print(f"  set_default_impl('kernel') forward ok "
              f"(var mean {float(jnp.mean(out_default.var)):.3e})")
    finally:
        set_default_impl("xla")

    print("== 6. Autotuning per-op schedules (paper §6) ==")
    # The kernel path above ran the fixed default block shapes. The tuner
    # discovers the model's actual (op, shape, dtype) set by tracing one
    # forward (zero FLOPs), searches each op's schedule space (wall clock
    # on TPU, cost-model ranking in interpret mode), and warms the
    # process-global schedule cache the dispatch registry consults.
    from repro.tuning import autotune
    from repro.tuning.cache import consult_digest, reset_global_cache

    chosen = autotune(mlp_forward, pfp_params, xs)
    for (op, shape_key, _, _), sched in chosen.items():
        print(f"  {op:12s} {str(shape_key):18s} -> {sched.describe()}")
    # The next kernel forward picks the tuned schedules up automatically...
    out_t = mlp_forward(pfp_params, xs, Context(mode=Mode.PFP, impl="kernel"))
    print(f"  cached-schedule forward ran: {consult_digest()}")
    # ...and stays at parity with the XLA stack.
    drift_t = float(jnp.max(jnp.abs(out_t.mean - out_x.mean)))
    print(f"  max |tuned kernel - xla| logit mean drift: {drift_t:.2e}")
    reset_global_cache()  # keep the demo hermetic
    # To persist: autotune(..., save_path='schedules.json') and later
    # repro.tuning.load_global_cache('schedules.json') (or run benchmarks
    # via `python benchmarks/run.py --tune --impl kernel`).

    print("== 7. Serving: uncertainty-aware continuous batching ==")
    # The engine (src/repro/serving/engine/, see its README.md) sustains a
    # request stream against a pooled decode batch: admission-controlled
    # scheduling, chunked prefill, ONE probabilistic pass per decode step
    # for the whole batch, and an uncertainty router that turns the free
    # per-token MI signal into continue / escalate-to-SVI / abstain.
    import dataclasses

    from repro.configs import reduced_config
    from repro.models import lm
    from repro.serving.engine import (Engine, EngineConfig, RouterConfig,
                                      UncertaintyRouter, poisson_trace,
                                      run_load)

    lm_cfg = dataclasses.replace(reduced_config("granite-8b"),
                                 sigma_init=5e-2)  # wide posteriors: the
    #                            router's gray zone actually gets traffic
    lm_params = svi_to_pfp(lm.init_params(lm_cfg, jax.random.PRNGKey(0)))
    engine = Engine(
        lm_cfg, lm_params,
        EngineConfig(slots=2, max_len=24, num_uncertainty_samples=16),
        router=UncertaintyRouter(lm_cfg, RouterConfig(
            mi_continue=0.02, mi_abstain=1.5, escalate_samples=4)))
    trace = poisson_trace(5, rate=0.7, vocab_size=lm_cfg.vocab_size,
                          seed=0, prompt_len=(3, 8), max_new_tokens=(2, 4))
    s = run_load(engine, trace)
    print(f"  served {s['completed']} requests / {s['tokens_generated']} "
          f"tokens in {s['steps']} engine steps "
          f"(abstained={s['abstained']}, escalations={s['escalations']})")
    print(f"  p50 latency {s['p50_latency_steps']:.1f} steps, slot pool "
          f"drained: final occupancy {s['final_occupancy']}")
    # `python -m repro.launch.serve --engine` runs this on a (data, model)
    # mesh; `python benchmarks/run.py --only serving` benchmarks it.

    print("== 8. Paged Gaussian KV-cache ==")
    # The same engine with EngineConfig(page_size=N) swaps the per-slot
    # max_len KV mean/variance buffers for a global pool of fixed-size
    # pages (uncertainty-aware paged attention: k_mu/v_mu/v_var page
    # together). Device memory then scales with cached TOKENS, not
    # slots*max_len — more concurrent requests per byte — and decode is
    # bit-for-bit identical to the contiguous layout.
    contiguous_tokens = {r.uid: list(r.generated) for r in engine.finished}
    paged_engine = Engine(
        lm_cfg, lm_params,
        EngineConfig(slots=2, max_len=24, num_uncertainty_samples=16,
                     page_size=4, auto_defrag=True),
        router=UncertaintyRouter(lm_cfg, RouterConfig(
            mi_continue=0.02, mi_abstain=1.5, escalate_samples=4)))
    trace = poisson_trace(5, rate=0.7, vocab_size=lm_cfg.vocab_size,
                          seed=0, prompt_len=(3, 8), max_new_tokens=(2, 4))
    sp = run_load(paged_engine, trace)
    paged_tokens = {r.uid: list(r.generated)
                    for r in paged_engine.finished}
    print(f"  paged (page_size=4) served the same tokens: "
          f"{paged_tokens == contiguous_tokens}")
    print(f"  page pool: peak occupancy "
          f"{sp['peak_page_occupancy']:.0%} of "
          f"{paged_engine.pool.total_pages} pages, "
          f"{sp['defrags']} defrags, {sp['preemptions']} preemptions, "
          f"drained to {sp['final_live_pages']} live pages")
    # `--page-size` on launch/serve.py and bench_serving.py drive this at
    # scale; the occupancy benchmark row shows the paged engine running
    # strictly more concurrent slots at equal device memory.

    print("== 9. Prefix sharing: copy-on-write pages for a system prompt ==")
    # PFP K/V rows are deterministic per (token, position), so requests
    # opening with the SAME system prompt would write identical leading
    # pages. With EngineConfig(prefix_sharing=True) the engine indexes
    # finished lineages' pages in a radix tree and maps them into new
    # requests at refcount+1: prefill runs only on the non-shared suffix
    # (bit-for-bit the same logits — paged attention reads through the
    # table), and a partially-shared boundary page is copied-on-write
    # before the first divergent token lands in it.
    system = np.arange(1, 13, dtype=np.int32)  # a 12-token "system prompt"

    def shared_trace():
        from repro.serving.engine import Request
        return [Request(uid=i,
                        prompt=np.concatenate(
                            [system, np.full(3, 40 + i, np.int32)]),
                        max_new_tokens=3, arrival=float(2 * i))
                for i in range(5)]

    def run_engine(prefix_sharing):
        eng = Engine(
            lm_cfg, lm_params,
            EngineConfig(slots=2, max_len=24, num_uncertainty_samples=16,
                         page_size=4, prefix_sharing=prefix_sharing),
            router=UncertaintyRouter(lm_cfg, RouterConfig(
                mi_continue=0.02, mi_abstain=1.5, escalate_samples=4)))
        summary = run_load(eng, shared_trace())
        return eng, summary

    cold_eng, cold = run_engine(False)
    shared_eng, sh = run_engine(True)
    same = ({r.uid: list(r.generated) for r in cold_eng.finished}
            == {r.uid: list(r.generated) for r in shared_eng.finished})
    print(f"  decode bit-for-bit vs cold prefill: {same}")
    print(f"  prefill tokens: cold={cold['prefill_tokens']} "
          f"shared={sh['prefill_tokens']} "
          f"(saved {sh['prefill_tokens_saved']}, "
          f"{sh['prefill_frac_saved']:.0%} of prefill FLOPs)")
    print(f"  prefix hits {sh['prefix_hits']} "
          f"(hit rate {sh['prefix_hit_rate']:.0%}), "
          f"{sh['cow_copies']} copy-on-write page copies, "
          f"{sh['final_prefix_held_pages']} pages retained for reuse")
    # `launch/serve.py --prefix-sharing --common-prefix K` runs this on a
    # mesh with refcount-leak checks; bench_serving's prefix_reuse row
    # pins the acceptance criteria (bit-for-bit + >= shared-fraction
    # prefill drop + more concurrency at equal page budget).

    print("== 10. Speculative decoding: draft with the mean, verify with "
          "one PFP pass ==")
    # With EngineConfig(speculate_k=K) each decode round drafts K-1 greedy
    # tokens with a mean-only (zero-variance) pass, then verifies the
    # whole block — head token + drafts — with ONE chunked PFP pass
    # through the paged cache. Verified tokens are served while they
    # match the draft and their MI stays under the CONTINUE gate, so one
    # full probabilistic pass amortizes over up to K served tokens. The
    # token stream is bit-for-bit plain decode (uncertainty sampling is
    # keyed per (request, token)); MI traces agree to float precision —
    # the K-wide verify pass accumulates its gemms in a different order
    # than the 1-wide decode pass. Narrow posteriors here keep the mean
    # draft on-distribution so acceptance stays high.
    spec_cfg = dataclasses.replace(lm_cfg, sigma_init=1e-3)
    spec_params = svi_to_pfp(lm.init_params(spec_cfg, jax.random.PRNGKey(0)))

    def run_spec(k):
        eng = Engine(
            spec_cfg, spec_params,
            EngineConfig(slots=2, max_len=24, num_uncertainty_samples=16,
                         page_size=4, speculate_k=k),
            router=UncertaintyRouter(spec_cfg, RouterConfig(
                mi_continue=0.02, mi_abstain=1.5, escalate_samples=4)))
        trace = poisson_trace(5, rate=0.7, vocab_size=spec_cfg.vocab_size,
                              seed=0, prompt_len=(3, 8),
                              max_new_tokens=(4, 8))
        summary = run_load(eng, trace)
        outs = {r.uid: (list(r.generated), [float(m) for m in r.mi_trace])
                for r in eng.finished}
        return outs, summary

    plain_out, plain_s = run_spec(0)
    spec_out, spec_s = run_spec(4)
    same_tokens = {u: v[0] for u, v in spec_out.items()} == \
        {u: v[0] for u, v in plain_out.items()}
    same_mi = all(np.allclose(spec_out[u][1], plain_out[u][1],
                              rtol=0.0, atol=2e-5) for u in plain_out)
    print(f"  speculative (K=4) vs plain decode: tokens bit-for-bit "
          f"{same_tokens}, MI traces within 2e-5 {same_mi}")
    print(f"  draft acceptance {spec_s['draft_acceptance_rate']:.0%}, "
          f"{spec_s['accepted_tokens_per_verify']:.1f} extra tokens per "
          f"verify pass")
    print(f"  full-PFP passes per served token: "
          f"plain={plain_s['pfp_passes_per_token']:.2f} -> "
          f"speculative={spec_s['pfp_passes_per_token']:.2f} (< 1.0: one "
          f"probabilistic pass now serves several tokens)")
    # `launch/serve.py --speculate K --expect-accept-rate R` runs this on
    # a mesh with a built-in parity check; bench_serving's speculative
    # row pins < 1.0 PFP passes per token plus the batched-escalation
    # amortization (at most one SVI pass per engine step).

    print("== 11. Fleet serving: two disaggregated replicas behind a "
          "prefix router ==")
    # A Fleet fronts R replicas with one admission router: each request
    # goes to the replica whose prefix index already caches the longest
    # prefix of its prompt (read-only peek, so routing never perturbs
    # retention), falling back to least-loaded. With disaggregate=True
    # each replica is a prefill engine + decode engine sharing one page
    # pool: the prompt prefills as a shadow request, the prefix index
    # takes refcounted holds on its pages, and the decode engine admits
    # the real request by mapping those pages — prefilling exactly ONE
    # token, so decode admission never waits behind a long prompt.
    # Every replica runs the single engine's pass shapes and sampling is
    # keyed per (request, token), so the routed fleet's tokens AND MI
    # traces are bit-for-bit a single engine's.
    from repro.serving.fleet import Fleet, FleetConfig

    def fleet_trace():
        from repro.serving.engine import Request
        system = np.arange(1, 10, dtype=np.int32)
        return [Request(uid=i,
                        prompt=np.concatenate(
                            [system, np.full(3, 50 + i, np.int32)]),
                        max_new_tokens=4, arrival=float(2 * i))
                for i in range(6)]

    fleet_ecfg = EngineConfig(slots=2, max_len=24,
                              num_uncertainty_samples=16, page_size=4,
                              prefix_sharing=True)
    fleet_router = UncertaintyRouter(spec_cfg, RouterConfig(
        mi_continue=0.02, mi_abstain=1.5, escalate_samples=4))
    single = Engine(spec_cfg, spec_params, fleet_ecfg, router=fleet_router)
    run_load(single, fleet_trace())
    fleet = Fleet(spec_cfg, spec_params, fleet_ecfg,
                  FleetConfig(replicas=2, disaggregate=True),
                  router=fleet_router)
    fs = run_load(fleet, fleet_trace())
    out = lambda e: {r.uid: (list(r.generated),  # noqa: E731
                             [float(m) for m in r.mi_trace])
                     for r in e.finished}
    print(f"  2-replica disaggregated fleet vs single engine: bit-for-bit "
          f"{out(fleet) == out(single)}")
    print(f"  routing: {fs['route_prefix_hits']} requests sent to a cached "
          f"prefix, {fs['route_fallbacks']} least-loaded fallbacks "
          f"(hit rate {fs['route_hit_rate']:.0%})")
    print(f"  disaggregation: {fs['handoffs']} prefill->decode handoffs, "
          f"p50 latency {fs['p50_handoff_steps']:.1f} steps, "
          f"{fs['decode_steps_during_peer_prefill']} decode steps served "
          f"during a peer prefill")
    # `launch/serve.py --replicas R --disaggregate` runs this on a mesh
    # with parity + page/hold-leak checks and a `--expect-route-hits`
    # floor; bench_serving's fleet row pins the acceptance criteria.

    print("== 12. Observability: traces, metrics, live per-op profile ==")
    # The whole serving stack instruments through repro.obs. A Tracer
    # records every lifecycle event keyed on (engine step, seq) — the
    # engine's step counter is the only time base, so two identical runs
    # produce byte-identical traces — and a fleet shares ONE tracer
    # across its frontend ('fleet' lane) and replicas ('r0.prefill',
    # 'r0.decode', ...). Metrics live in per-engine registries
    # (Counter/Gauge/Histogram families) with deterministic snapshots
    # and a Prometheus text export; escalations double as free
    # calibration audits (mi_ece) and high-MI tokens count OOD alarms.
    import json as _json

    from repro.obs.trace import Tracer

    tracer = Tracer()
    fleet2 = Fleet(spec_cfg, spec_params, fleet_ecfg,
                   FleetConfig(replicas=2, disaggregate=True),
                   router=fleet_router, tracer=tracer)
    os = run_load(fleet2, fleet_trace())
    events = tracer.events
    kinds = sorted({e["event"] for e in events})
    print(f"  {len(events)} trace events across "
          f"{len({e['lane'] for e in events})} lanes: {', '.join(kinds)}")
    # Write the Perfetto view: drop this file onto https://ui.perfetto.dev
    # and each lane becomes a track with per-request lifetime spans.
    chrome = tracer.to_chrome()
    print(f"  Chrome trace-event export: {len(chrome['traceEvents'])} "
          f"entries (tracer.write_chrome('trace.chrome.json') to save)")
    # Uncertainty telemetry rides the same summaries: band totals pool
    # across replicas by summation; calibration (mi_ece) stays per-engine
    # because an error RATE does not sum.
    dec_s = fleet2.replicas[0].decode_engine.metrics.summary()
    print(f"  router bands: continue={os['band_continue']} "
          f"escalate={os['band_escalate']} abstain={os['band_abstain']}, "
          f"ood_alarms={os['ood_alarms']}, "
          f"r0.decode mi_ece={dec_s['mi_ece']:.3f}")
    # Per-lane Prometheus export (one registry per engine):
    dec0 = fleet2.replicas[0].decode_engine.metrics.registry
    prom = dec0.to_prometheus(extra_labels={"lane": "r0.decode"})
    sample = [ln for ln in prom.splitlines()
              if ln.startswith("repro_tokens_generated")][0]
    print(f"  Prometheus sample: {sample}")
    # And the live Table-4 per-op profile of the serving forward:
    from repro.obs.profiler import profile_ops
    eng0 = fleet2.replicas[0].decode_engine
    feed = jnp.zeros((eng0.config.slots, 1), jnp.int32)
    zeros = jnp.zeros(eng0.config.slots, jnp.int32)
    with profile_ops() as prof:  # eager, per-op block_until_ready fences
        eng0.decode_fn(eng0.params, feed, feed, zeros,
                       jnp.zeros(eng0.config.slots, bool), eng0.pool.states,
                       eng0.pool.device_table(), *eng0.logit_buffers)
    top = prof.table()[0]
    print(f"  per-op decode profile: {len(prof.table())} ops, top = "
          f"{top['op']} at {top['frac']:.0%} of pass time")
    _ = _json.dumps(tracer.to_chrome())  # both exports are plain JSON
    # `launch/serve.py --trace-out t.jsonl --metrics-out m.json --prom-out
    # m.prom --profile-ops` exports all of this from a real run, and
    # `python -m repro.obs.validate` schema-checks the artifacts (the CI
    # obs-smoke job's gate).

    print("== 13. Warm-start fleet schedule DB: tune once, serve warm ==")
    # A fleet replica should never search schedules on its hot path. The
    # COLD replica records every (op, shape, dtype, backend) its forward
    # consults, tunes the missing entries (cost-model 'rank' mode here —
    # free; wall-clock on TPU) and atomically merge-saves the per-backend
    # DB — concurrent replicas flushing the same path merge instead of
    # corrupting each other. Every WARM replica preloads the DB and the
    # consult counters prove zero search ever ran.
    import os as _os
    import tempfile as _tempfile

    from repro.tuning import cache as sched_cache
    from repro.tuning import measure as sched_measure

    db_path = _os.path.join(_tempfile.mkdtemp(), "fleet_schedules.json")
    reset_global_cache()
    with sched_cache.record_shapes() as queries:  # --- the cold replica
        mlp_forward(pfp_params, xs, Context(mode=Mode.PFP, impl="kernel"))
    cold = sched_cache.consult_counters()
    cache = sched_cache.global_cache()
    for op, shape_key, dtype, backend in dict.fromkeys(queries):
        if cache.get(op, shape_key, dtype, backend) is None:
            sched_measure.tune_into_cache(cache, op, shape_key, dtype,
                                          backend, mode="rank")
    cache.save(db_path)  # temp-file + atomic rename, merge-on-conflict
    print(f"  cold replica: {cold['misses']} cache misses -> tuned and "
          f"saved {len(cache)} entries to {_os.path.basename(db_path)}")
    reset_global_cache()  # --- a warm replica is a fresh process
    sched_cache.load_global_cache(db_path)
    mlp_forward(pfp_params, xs, Context(mode=Mode.PFP, impl="kernel"))
    warm = sched_cache.consult_counters()
    print(f"  warm replica: {warm['consults']} consults = {warm['hits']} "
          f"hits + {warm['misses']} misses (zero schedule search)")
    assert warm["misses"] == 0, warm
    reset_global_cache()  # keep the demo hermetic
    # launch/serve.py wires this exact flow for real fleets:
    #   serve --impl kernel --fuse-ops --save-schedule-db db.json   (cold)
    #   serve --impl kernel --fuse-ops --schedule-db db.json \
    #         --expect-warm-cache                                   (warm)

    print("== 14. Uncertainty-aware MoE decode (DeepSeek-style routing) ==")
    # A Mixture-of-Experts LM through the same engine: the router picks
    # top-k experts per token on the MEAN path (deterministic control
    # flow), while the Gaussian moments ride through ONE grid-level
    # batched-expert Pallas call per MoE layer (kernels/pfp_moe.py —
    # E independent Eq. 12 dense problems, expert axis on the grid).
    # Decode runs the aux-loss-free path: no load-balance loss term in
    # the graph, but the capacity-drop accounting still surfaces through
    # the engine's moe_drop_rate gauge.
    from repro.configs import reduced_config
    from repro.models import lm as lmmod
    from repro.serving.engine import (Engine, EngineConfig, RequestScheduler,
                                      RouterConfig, SchedulerConfig,
                                      UncertaintyRouter, poisson_trace,
                                      run_load)

    moe_cfg = reduced_config("deepseek-moe-16b")
    moe_params = svi_to_pfp(lmmod.init_params(moe_cfg, jax.random.PRNGKey(7)))
    engine = Engine(
        moe_cfg, moe_params,
        EngineConfig(slots=2, max_len=24, seed=0),
        router=UncertaintyRouter(moe_cfg, RouterConfig(escalate_samples=4)),
        scheduler=RequestScheduler(SchedulerConfig(prefill_chunk=8),
                                   max_len=24))
    summary = run_load(engine, poisson_trace(
        4, rate=0.5, vocab_size=moe_cfg.vocab_size, seed=3,
        prompt_len=(4, 8), max_new_tokens=(2, 4)))
    print(f"  served {summary['completed']} requests through "
          f"{moe_cfg.num_experts} experts (top-{moe_cfg.top_k}): "
          f"{summary['moe_assignments']:.0f} routed assignments, "
          f"{summary['moe_dropped_assignments']:.0f} dropped at capacity "
          f"(drop rate {summary['moe_drop_rate']:.3f})")
    assert summary["final_occupancy"] == 0  # the MoE pool drains too
    # `--arch deepseek-moe-16b --impl kernel` on launch/serve.py runs this
    # with the batched-expert kernel + tuned dense_batched schedules;
    # ModelConfig(moe_dispatch='a2a') flips dispatch/combine to explicit
    # shard_map all-to-alls on a (data, model) mesh (nn/moe.py).


if __name__ == "__main__":
    main()
