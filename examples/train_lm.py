"""End-to-end driver: SVI-train a Bayesian decoder LM, convert, PFP-decode.

Defaults run a ~8M-parameter granite-family model for 100 steps in a few
minutes on CPU; ``--preset 100m --steps 300`` is the full-size run this
driver is written for (same code path the pod launcher uses: checkpointing,
step monitoring, deterministic restartable data).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps N] [--preset 100m]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bayes.convert import svi_to_pfp
from repro.bayes.variational import KLSchedule
from repro.configs import get_config
from repro.core.modes import Mode
from repro.data.tokens import TokenPipeline
from repro.models import lm
from repro.nn.module import Context
from repro.training.checkpoint import CheckpointManager
from repro.training.fault_tolerance import StepMonitor
from repro.training.optimizer import Adam, cosine_schedule
from repro.training.train_loop import init_train_state, make_svi_train_step


def make_cfg(preset: str):
    base = get_config("granite-8b")
    if preset == "100m":
        return dataclasses.replace(
            base, num_layers=8, d_model=640, num_heads=10, num_kv_heads=2,
            head_dim=64, d_ff=1792, vocab_size=8192)
    return dataclasses.replace(
        base, num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=768, vocab_size=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--preset", default="small", choices=["small", "100m"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/pfp_lm_ckpt")
    args = ap.parse_args()

    cfg = make_cfg(args.preset)
    print(f"model: {cfg.name}-style, ~{cfg.param_count() / 1e6:.0f}M params")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=0)
    num_data = args.batch * args.seq * args.steps

    def fwd(p, batch, ctx):
        logits, aux, _ = lm.forward(p, cfg, batch, ctx)
        return logits, aux

    opt = Adam(learning_rate=cosine_schedule(3e-3, 20, args.steps),
               clip_norm=1.0)
    step = jax.jit(make_svi_train_step(
        fwd, opt, num_data=num_data,
        kl_schedule=KLSchedule(0.25, args.steps)))
    state = init_train_state(params, opt)

    mgr = CheckpointManager(args.ckpt_dir, keep_last=2)
    monitor = StepMonitor()
    losses = []
    for i in range(args.steps):
        t0 = time.perf_counter()
        batch = jax.tree_util.tree_map(jnp.asarray, pipe.batch(i))
        state, m = step(state, batch, jax.random.PRNGKey(i))
        dt = time.perf_counter() - t0
        verdict = monitor.record(i, dt)
        losses.append(float(m["loss"]))
        if i % 10 == 0 or verdict == "straggle":
            print(f"step {i:4d} loss={losses[-1]:.3f} "
                  f"nll={float(m['nll']):.3f} {dt * 1e3:.0f}ms [{verdict}]")
        if (i + 1) % 50 == 0:
            mgr.save(i + 1, state)          # async snapshot
    mgr.wait()
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(learned bigram structure: {'yes' if losses[-1] < losses[0] - 0.5 else 'partial'})")

    print("== convert to PFP and decode with uncertainty ==")
    pfp_params = svi_to_pfp(state.params, dtype=jnp.float32)
    ctx = Context(mode=Mode.PFP)
    prompt = jnp.asarray(pipe.batch(999)["tokens"][:2, :16])
    last, states = lm.prefill(pfp_params, cfg, {"tokens": prompt}, ctx,
                              max_len=32)
    from repro.serving.decode import uncertainty_decode

    pos = 16
    for t in range(6):
        out = uncertainty_decode(last.mean, last.var, jax.random.PRNGKey(t))
        print(f"  token={np.asarray(out.token)} "
              f"MI={np.asarray(out.mutual_info).round(3)} "
              f"abstain={np.asarray(out.abstain)}")
        dec_in = {"tokens": out.token[:, None],
                  "positions": jnp.full((2, 1), pos, jnp.int32),
                  "cache_len": jnp.full((2,), pos + 1, jnp.int32)}
        last_l, states = lm.decode_step(pfp_params, cfg, dec_in, states, ctx)
        last = last_l
        pos += 1


if __name__ == "__main__":
    main()
