"""Dirty-MNIST OOD study with LeNet-5 (paper Table 1 / Figs 3-4 pipeline).

Trains the paper's LeNet-5 with SVI, converts to PFP, fits the variance
calibration factor on a validation split, and reports the uncertainty
decomposition per split (clean / ambiguous / OOD) for both methods.

Run:  PYTHONPATH=src python examples/ood_detection.py  [--quick]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.bayes import metrics as bm
from repro.bayes.convert import fit_calibration_factor, svi_to_pfp
from repro.bayes.variational import KLSchedule
from repro.core.modes import Mode
from repro.data.dirty_mnist import batches, dirty_mnist
from repro.models.simple import lenet5_forward, lenet5_init
from repro.nn.module import Context
from repro.training.optimizer import Adam
from repro.training.train_loop import init_train_state, make_svi_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    n_train = 800 if args.quick else 3000
    epochs = 6 if args.quick else 30

    (x_train, y_train), evals = dirty_mnist(n_train=n_train, n_eval=300)
    params = lenet5_init(jax.random.PRNGKey(0), sigma_init=1e-3)

    def fwd(p, batch, ctx):
        return lenet5_forward(p, batch["x"][..., None], ctx), 0.0

    opt = Adam(learning_rate=2e-3)
    step = jax.jit(make_svi_train_step(
        fwd, opt, num_data=n_train, kl_schedule=KLSchedule(0.25, 200)))
    state = init_train_state(params, opt)
    for i, (bx, by) in enumerate(batches(x_train, y_train, 50, epochs=epochs)):
        state, m = step(state, {"x": jnp.asarray(bx),
                                "targets": jnp.asarray(by)},
                        jax.random.PRNGKey(i))
        if i % 50 == 0:
            print(f"step {i:4d} loss={float(m['loss']):.3f}")

    def pfp_metrics(p, imgs, key):
        out = lenet5_forward(p, jnp.asarray(imgs)[..., None],
                             Context(mode=Mode.PFP))
        return bm.pfp_predictive_metrics(key, out.mean, out.var, 50)

    print("== calibration factor line search (paper §4) ==")

    def eval_cal(cal):
        p = svi_to_pfp(state.params, calibration_factor=cal)
        mo = pfp_metrics(p, evals["ood"][0], jax.random.PRNGKey(1))
        mc = pfp_metrics(p, evals["clean"][0], jax.random.PRNGKey(2))
        return bm.auroc(np.asarray(mo["total"]), np.asarray(mc["total"]))

    cal, auroc = fit_calibration_factor(eval_cal)
    print(f"calibration factor = {cal} (paper used 0.4 for LeNet-5), "
          f"AUROC = {auroc:.3f}")

    p = svi_to_pfp(state.params, calibration_factor=cal)
    print(f"{'split':12s} {'acc':>6s} {'total':>7s} {'SME':>7s} {'MI':>7s}")
    for split in ("clean", "ambiguous", "ood"):
        imgs, labels = evals[split]
        m = pfp_metrics(p, imgs, jax.random.PRNGKey(3))
        acc = (np.asarray(m["pred"]) == labels).mean() \
            if labels is not None else float("nan")
        print(f"{split:12s} {acc:6.3f} {float(np.mean(m['total'])):7.3f} "
              f"{float(np.mean(m['aleatoric'])):7.3f} "
              f"{float(np.mean(m['mi'])):7.3f}")
    print("expected pattern (paper Fig. 3): ambiguous -> high SME; "
          "ood -> high MI")


if __name__ == "__main__":
    main()
